//! Serving metrics: latency recorder (TBT, per-request), throughput,
//! memory accounting — the paper's §5 measurement set.

use std::time::Instant;

use crate::util::stats::{summarize, Summary};

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// wall seconds per decode step (time-between-tokens)
    pub tbt: Vec<f64>,
    /// simulated seconds per decode step
    pub sim_tbt: Vec<f64>,
    /// tokens generated
    pub tokens: u64,
    /// prefill tokens absorbed
    pub prefill_tokens: u64,
    /// prefill chunk steps executed (one artifact call each); together with
    /// the batcher's decode-step count this gives the prefill/decode
    /// interleave ratio exported on `/v1/metrics`
    pub prefill_chunks: u64,
    /// streamed response chunks flushed to clients (token lines + final
    /// summary lines over chunked transfer encoding)
    pub stream_flushes: u64,
    /// bytes moved GPU→CPU by evictions (simulated PCIe)
    pub evict_bytes: u64,
    /// peak memory observations
    pub peak_gpu_kv_bytes: usize,
    pub peak_cpu_kv_bytes: usize,
    /// wall seconds from sparse-attention submit to merge-ready (the
    /// submitter's wait). Under overlapped execution this span also covers
    /// the caller's own KV bookkeeping, so it is a *latency* figure, not a
    /// CPU-work figure — that's `cpu_attn_busy_secs`.
    pub cpu_attn_wait_secs: f64,
    /// summed pool-side task execution seconds (workers + caller-assist)
    /// for the engine's sparse submissions — the honest CPU-work figure
    pub cpu_attn_busy_secs: f64,
    /// serial bookkeeping seconds that ran concurrently with an in-flight
    /// sparse submission — the time the overlap hid (0 when the engine
    /// runs forced-sequential)
    pub cpu_attn_overlap_secs: f64,
    /// (row, head) jobs submitted to the CPU attention pool
    pub cpu_attn_jobs: u64,
    /// packed tasks those jobs became (≈ jobs / adjacent-head merge factor)
    pub cpu_attn_tasks: u64,
    /// requests retired by explicit cancellation (`/v1/cancel` or a token
    /// trip)
    pub requests_cancelled: u64,
    /// requests retired because their deadline passed (partial tokens are
    /// still delivered)
    pub requests_deadline_expired: u64,
    /// requests retired because the client stopped reading its stream
    pub requests_disconnected: u64,
    /// requests rejected by admission control (watermark 429s) or shed
    /// from the queue after exceeding their max-queue-wait bound
    pub requests_shed: u64,
    /// requests rejected because their KV block requirement exceeds the
    /// pool's total capacity — unlike a shed, retrying cannot succeed
    /// without a larger `--kv-blocks` (the "won't-ever-fit" 429)
    pub requests_rejected_capacity: u64,
    /// (sequence, layer, head) CPU stores currently on each tier — gauges,
    /// refreshed every engine step (`--kv-tier`; f32 is the only non-zero
    /// one under the default mode)
    pub kv_tier_f32: u64,
    pub kv_tier_int8: u64,
    pub kv_tier_window: u64,
    /// heads currently holding int8-quantized CPU KV (== `kv_tier_int8`;
    /// kept as its own counter so dashboards keying on quantization don't
    /// have to know the tier taxonomy)
    pub kv_quant_heads: u64,
    /// bytes the int8 tiers currently save vs f32 storage of the same
    /// entries (gauge; Σ over int8 heads of `f32_bytes − quant_bytes`)
    pub kv_quant_bytes_saved: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_step(&mut self, wall: f64, sim: f64, new_tokens: u64) {
        self.tbt.push(wall);
        self.sim_tbt.push(sim);
        self.tokens += new_tokens;
    }

    pub fn observe_memory(&mut self, gpu: usize, cpu: usize) {
        self.peak_gpu_kv_bytes = self.peak_gpu_kv_bytes.max(gpu);
        self.peak_cpu_kv_bytes = self.peak_cpu_kv_bytes.max(cpu);
    }

    /// Account one CPU sparse-attention submission: `wait_secs` is the
    /// submit→merge-ready wall span on the engine thread, `busy_secs` the
    /// pool-side execution time of the submission's tasks.
    pub fn observe_cpu_attn(&mut self, wait_secs: f64, busy_secs: f64, jobs: u64, tasks: u64) {
        self.cpu_attn_wait_secs += wait_secs;
        self.cpu_attn_busy_secs += busy_secs;
        self.cpu_attn_jobs += jobs;
        self.cpu_attn_tasks += tasks;
    }

    /// Account bookkeeping time that ran while a sparse submission was in
    /// flight (the overlap win; 0 under forced-sequential stepping).
    pub fn observe_cpu_attn_overlap(&mut self, secs: f64) {
        self.cpu_attn_overlap_secs += secs;
    }

    /// Refresh the KV-tier gauges (per engine step: current per-head tier
    /// census across every sequence × layer, and the bytes the int8 tiers
    /// save right now).
    pub fn observe_kv_tiers(
        &mut self,
        f32_heads: u64,
        int8_heads: u64,
        window_heads: u64,
        bytes_saved: u64,
    ) {
        self.kv_tier_f32 = f32_heads;
        self.kv_tier_int8 = int8_heads;
        self.kv_tier_window = window_heads;
        self.kv_quant_heads = int8_heads;
        self.kv_quant_bytes_saved = bytes_saved;
    }

    pub fn tbt_summary(&self) -> Option<Summary> {
        (!self.tbt.is_empty()).then(|| summarize(&self.tbt))
    }

    pub fn sim_tbt_summary(&self) -> Option<Summary> {
        (!self.sim_tbt.is_empty()).then(|| summarize(&self.sim_tbt))
    }

    /// tokens per (wall) second across recorded steps
    pub fn throughput(&self) -> f64 {
        let total: f64 = self.tbt.iter().sum();
        if total > 0.0 {
            self.tokens as f64 / total
        } else {
            0.0
        }
    }

    pub fn sim_throughput(&self) -> f64 {
        let total: f64 = self.sim_tbt.iter().sum();
        if total > 0.0 {
            self.tokens as f64 / total
        } else {
            0.0
        }
    }
}

/// RAII wall-clock timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut m = Metrics::new();
        m.record_step(0.5, 0.1, 2);
        m.record_step(0.5, 0.1, 2);
        assert!((m.throughput() - 4.0).abs() < 1e-9);
        assert!((m.sim_throughput() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn peaks_are_max() {
        let mut m = Metrics::new();
        m.observe_memory(10, 5);
        m.observe_memory(3, 8);
        assert_eq!(m.peak_gpu_kv_bytes, 10);
        assert_eq!(m.peak_cpu_kv_bytes, 8);
    }

    #[test]
    fn empty_summary_none() {
        assert!(Metrics::new().tbt_summary().is_none());
    }

    #[test]
    fn kv_tier_gauges_overwrite_not_accumulate() {
        let mut m = Metrics::new();
        m.observe_kv_tiers(4, 3, 1, 1000);
        m.observe_kv_tiers(2, 5, 1, 900);
        assert_eq!(m.kv_tier_f32, 2);
        assert_eq!(m.kv_tier_int8, 5);
        assert_eq!(m.kv_tier_window, 1);
        assert_eq!(m.kv_quant_heads, 5, "quant-head gauge mirrors the int8 tier");
        assert_eq!(m.kv_quant_bytes_saved, 900);
    }
}
