//! Attention-placement scenarios: the simulated-time arithmetic behind the
//! paper's micro-benchmarks (Figs. 6, 10, 11) and end-to-end curves
//! (Figs. 12–14). Each scenario returns a labeled Breakdown so benches can
//! print stacked bars matching the paper's plots.

use super::clock::Breakdown;
use super::device::{AttnWork, DeviceSpec};
use super::interconnect::Interconnect;
use crate::config::ModelConfig;

/// Achieved-fraction-of-roofline de-rates (attention kernels don't hit
/// nameplate). Values chosen from published FlashAttention/GEMV utilization
/// figures; held constant across all scenarios so *ratios* are fair.
pub const GPU_ATTN_EFF: f64 = 0.75;
pub const CPU_ATTN_EFF: f64 = 0.60;
pub const GPU_GEMM_EFF: f64 = 0.80;

#[derive(Debug, Clone)]
pub struct Testbed {
    pub gpu: DeviceSpec,
    pub cpu: DeviceSpec,
    pub link: Interconnect,
}

impl Testbed {
    /// The paper's evaluation platform (§5).
    pub fn paper() -> Testbed {
        Testbed {
            gpu: DeviceSpec::a6000(),
            cpu: DeviceSpec::xeon6430(),
            link: Interconnect::pcie4x16(),
        }
    }
}

impl Testbed {
    /// GPU attention with all KV resident on the GPU (the ideal in Fig. 1).
    pub fn gpu_resident_attention(&self, w: &AttnWork) -> Breakdown {
        let mut b = Breakdown::new();
        b.add("gpu_attn", self.gpu.op_time(w.flops(), w.bytes(), GPU_ATTN_EFF));
        b
    }

    /// GPU attention that must first load `cpu_kv` entries from host memory
    /// over PCIe (the FlexGen/offload baseline in Figs. 6/10/11). KV already
    /// on the GPU (`gpu_kv` entries) needs no transfer.
    pub fn gpu_attention_with_load(&self, w_total: &AttnWork, cpu_kv: usize) -> Breakdown {
        let mut b = Breakdown::new();
        let load = AttnWork { n_kv: cpu_kv, ..*w_total };
        b.add("pcie_kv_load", self.link.transfer_time(load.kv_bytes()));
        b.add(
            "gpu_attn",
            self.gpu.op_time(w_total.flops(), w_total.bytes(), GPU_ATTN_EFF),
        );
        b
    }

    /// CPU attention over `w` (dense or sparse-selected entries).
    pub fn cpu_attention(&self, w: &AttnWork) -> Breakdown {
        let mut b = Breakdown::new();
        b.add("cpu_attn", self.cpu.op_time(w.flops(), w.bytes(), CPU_ATTN_EFF));
        b
    }

    /// HGCA hybrid attention (Algorithm 2): GPU dense over the window runs
    /// concurrently with CPU sparse over the selected context; the merge
    /// moves only (O_cpu, lse_cpu) over the link. Returns (wall, breakdown);
    /// the breakdown keeps both devices' busy time like the paper's bars.
    pub fn hybrid_attention(
        &self,
        w_gpu: &AttnWork,
        w_cpu: &AttnWork,
        merge_bytes: f64,
    ) -> (f64, Breakdown) {
        let t_gpu = self.gpu.op_time(w_gpu.flops(), w_gpu.bytes(), GPU_ATTN_EFF);
        let t_cpu = self.cpu.op_time(w_cpu.flops(), w_cpu.bytes(), CPU_ATTN_EFF);
        let t_merge = self.link.transfer_time(merge_bytes);
        let mut b = Breakdown::new();
        b.add("gpu_attn", t_gpu);
        b.add("cpu_attn", t_cpu);
        b.add("merge", t_merge);
        (t_gpu.max(t_cpu) + t_merge, b)
    }

    /// Merge payload (O_cpu + lse per head) for a batch, fp32.
    pub fn merge_bytes(batch: usize, heads: usize, d_head: usize) -> f64 {
        (batch * heads * (d_head + 1)) as f64 * 4.0
    }

    /// Non-attention per-token cost of one decode step: stream the resident
    /// weights (memory-bound GEMV) and move CPU-resident weights over PCIe
    /// (FlexGen-style overlap: transfer hides under compute, take max).
    pub fn decode_step_weights(&self, model: &ModelConfig, batch: usize, gpu_weight_frac: f64) -> Breakdown {
        let wbytes = model.weight_bytes() as f64;
        let flops = 2.0 * model.param_count() as f64 * batch as f64;
        let compute = self.gpu.op_time(flops, wbytes, GPU_GEMM_EFF);
        let offload = wbytes * (1.0 - gpu_weight_frac);
        let transfer = self.link.transfer_time(offload);
        let mut b = Breakdown::new();
        b.add("gpu_ffn", compute);
        if offload > 0.0 {
            b.add("pcie_weights", (transfer - compute).max(0.0)); // overlapped
        }
        b
    }

    /// Prefill cost for `n_tokens` of prompt (compute-bound GEMM).
    pub fn prefill_weights(&self, model: &ModelConfig, batch: usize, n_tokens: usize) -> f64 {
        let flops = 2.0 * model.param_count() as f64 * (batch * n_tokens) as f64;
        self.gpu
            .op_time(flops, model.weight_bytes() as f64, GPU_GEMM_EFF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn work(n_query: usize, n_kv: usize, batch: usize) -> AttnWork {
        AttnWork {
            batch,
            heads: 32,
            d_head: 128,
            n_query,
            n_kv,
            bytes_per_el: 2,
        }
    }

    #[test]
    fn paper_o3_cpu_competitive_with_gpu_plus_load() {
        // O-3: for decode (q=1), CPU attention ≈ GPU attention + PCIe load
        let tb = Testbed::paper();
        let w = work(1, 8192, 1);
        let cpu = tb.cpu_attention(&w).total();
        let gpu_load = tb.gpu_attention_with_load(&w, 8192).total();
        assert!(
            cpu < gpu_load,
            "cpu {cpu} should beat gpu+load {gpu_load} at q=1"
        );
    }

    #[test]
    fn gpu_wins_when_kv_resident() {
        let tb = Testbed::paper();
        let w = work(1, 8192, 1);
        let gpu = tb.gpu_resident_attention(&w).total();
        let cpu = tb.cpu_attention(&w).total();
        assert!(gpu < cpu);
    }

    #[test]
    fn hybrid_beats_offload_at_long_context() {
        // Fig. 10's warm region: lots of CPU-resident KV, decode
        let tb = Testbed::paper();
        let w_gpu = work(1, 1024, 4);
        let w_cpu_sparse = work(1, 16384 / 5, 4); // β≈1 keeps ~20%
        let w_total = work(1, 1024 + 16384, 4);
        let (hybrid, _) =
            tb.hybrid_attention(&w_gpu, &w_cpu_sparse, Testbed::merge_bytes(4, 32, 128));
        let offload = tb.gpu_attention_with_load(&w_total, 16384).total();
        assert!(
            offload / hybrid > 2.0,
            "expected >2x speedup, got {}",
            offload / hybrid
        );
    }

    #[test]
    fn merge_transfer_negligible_vs_kv_transfer() {
        let tb = Testbed::paper();
        let mb = Testbed::merge_bytes(4, 32, 128);
        let w = work(1, 16384, 4);
        assert!(tb.link.transfer_time(mb) < 0.01 * tb.link.transfer_time(w.kv_bytes()));
    }

    #[test]
    fn append_amortizes_transfer() {
        // Fig. 6: at query size 32 GPU+load roughly matches CPU
        let tb = Testbed::paper();
        let w = work(32, 8192, 1);
        let cpu = tb.cpu_attention(&w).total();
        let gpu_load = tb.gpu_attention_with_load(&w, 8192).total();
        let ratio = gpu_load / cpu;
        assert!(ratio > 0.5 && ratio < 3.0, "ratio {ratio}");
    }

    #[test]
    fn decode_weights_offload_adds_pcie_time() {
        let tb = Testbed::paper();
        let model = crate::config::model::simulated("opt-30b").unwrap();
        let full = tb.decode_step_weights(&model, 4, 1.0).total();
        let offl = tb.decode_step_weights(&model, 4, 0.75).total();
        assert!(offl > full);
    }
}
