//! Host↔device interconnect model (PCIe 4.0 by default).
//!
//! The paper's Fig. 6/10/11 show KV-cache transfers over PCIe dominating
//! GPU-attention latency; this module provides the transfer-time arithmetic
//! those benches use, including the tiny zero-copy merge transfer HGCA
//! performs instead of moving raw KV tensors.

#[derive(Debug, Clone, PartialEq)]
pub struct Interconnect {
    pub name: String,
    /// Unidirectional bandwidth, bytes/s.
    pub bandwidth: f64,
    /// Per-transfer latency (DMA setup + driver), seconds.
    pub latency: f64,
    /// Achievable fraction of nameplate bandwidth for large DMA (0–1).
    pub efficiency: f64,
}

impl Interconnect {
    /// PCIe 4.0 x16: 32 GB/s nameplate (paper §1), ~85% achievable,
    /// ~10 µs per transfer setup.
    pub fn pcie4x16() -> Interconnect {
        Interconnect {
            name: "pcie4x16".into(),
            bandwidth: 32e9,
            latency: 10e-6,
            efficiency: 0.85,
        }
    }

    /// Time to move `bytes` in one DMA.
    pub fn transfer_time(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        self.latency + bytes / (self.bandwidth * self.efficiency)
    }

    /// Time for `n` separate transfers of `bytes` each (un-batched
    /// per-token offload — what HGCA's block-granular eviction avoids).
    pub fn transfer_time_n(&self, n: usize, bytes: f64) -> f64 {
        self.latency * n as f64 + (n as f64 * bytes) / (self.bandwidth * self.efficiency)
    }

    /// Effective bytes/s for a given transfer size (latency amortization).
    pub fn effective_bandwidth(&self, bytes: f64) -> f64 {
        bytes / self.transfer_time(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bytes_is_free() {
        assert_eq!(Interconnect::pcie4x16().transfer_time(0.0), 0.0);
    }

    #[test]
    fn large_transfer_approaches_nameplate() {
        let link = Interconnect::pcie4x16();
        let eff = link.effective_bandwidth(1e9);
        assert!(eff > 0.95 * link.bandwidth * link.efficiency);
    }

    #[test]
    fn small_transfers_are_latency_bound() {
        let link = Interconnect::pcie4x16();
        // a 4 KiB merge payload is ~latency-only
        let t = link.transfer_time(4096.0);
        assert!(t < 2.0 * link.latency);
        // effective bandwidth collapses
        assert!(link.effective_bandwidth(4096.0) < 0.02 * link.bandwidth);
    }

    #[test]
    fn batched_beats_per_token_offload() {
        // HGCA's block-granular eviction (Algorithm 1 footnote): one block
        // of 32 tokens beats 32 per-token DMAs
        let link = Interconnect::pcie4x16();
        let tok_bytes = 16384.0; // opt-6.7b per-layer per-token KV
        let batched = link.transfer_time(32.0 * tok_bytes);
        let unbatched = link.transfer_time_n(32, tok_bytes);
        assert!(unbatched > batched * 1.5);
    }

    #[test]
    fn merge_payload_orders_of_magnitude_smaller_than_kv() {
        // paper §3.3: O_cpu + lse is orders of magnitude smaller than raw KV.
        // opt-6.7b, batch 1: per-layer merge payload = H*dh + H floats fp32
        let merge_bytes = (32 * 128 + 32) as f64 * 4.0;
        let kv_bytes_16k = 2.0 * 32.0 * 128.0 * 16384.0 * 2.0;
        assert!(kv_bytes_16k / merge_bytes > 1000.0);
        let link = Interconnect::pcie4x16();
        assert!(link.transfer_time(merge_bytes) < link.transfer_time(kv_bytes_16k) / 100.0);
    }
}
