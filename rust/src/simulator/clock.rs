//! Labeled time breakdowns + a simulated clock.
//!
//! Benches report *where* simulated time goes (GPU compute, PCIe, CPU
//! compute, merge) exactly like the paper's Fig. 6/11 stacked bars.

use std::collections::BTreeMap;

/// An ordered list of (label, seconds) segments.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Breakdown {
    pub segments: Vec<(String, f64)>,
}

impl Breakdown {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, label: &str, secs: f64) -> &mut Self {
        self.segments.push((label.to_string(), secs));
        self
    }

    pub fn total(&self) -> f64 {
        self.segments.iter().map(|(_, s)| s).sum()
    }

    pub fn get(&self, label: &str) -> f64 {
        self.segments
            .iter()
            .filter(|(l, _)| l == label)
            .map(|(_, s)| s)
            .sum()
    }

    /// Merge another breakdown's segments into this one (summing by label,
    /// preserving first-seen order).
    pub fn absorb(&mut self, other: &Breakdown) {
        for (l, s) in &other.segments {
            if let Some(seg) = self.segments.iter_mut().find(|(sl, _)| sl == l) {
                seg.1 += s;
            } else {
                self.segments.push((l.clone(), *s));
            }
        }
    }

    /// Collapse duplicate labels.
    pub fn collapsed(&self) -> Breakdown {
        let mut order = Vec::new();
        let mut sums: BTreeMap<String, f64> = BTreeMap::new();
        for (l, s) in &self.segments {
            if !sums.contains_key(l) {
                order.push(l.clone());
            }
            *sums.entry(l.clone()).or_insert(0.0) += s;
        }
        Breakdown {
            segments: order.into_iter().map(|l| (l.clone(), sums[&l])).collect(),
        }
    }
}

/// Simulated wall clock for end-to-end runs: serial sections accumulate;
/// `parallel` takes the max of two concurrent sections (the paper's
/// CPU∥GPU overlap in Algorithm 2).
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    pub now: f64,
    pub breakdown: Breakdown,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance(&mut self, label: &str, secs: f64) {
        self.now += secs;
        self.breakdown.add(label, secs);
    }

    /// Two sections run concurrently; wall time advances by the max. The
    /// breakdown records both (so stacked bars still show each device's
    /// busy time) plus an `overlap_saved` credit segment.
    pub fn parallel(&mut self, a: (&str, f64), b: (&str, f64)) {
        let wall = a.1.max(b.1);
        self.now += wall;
        self.breakdown.add(a.0, a.1);
        self.breakdown.add(b.0, b.1);
        self.breakdown.add("overlap_saved", wall - a.1 - b.1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total_and_get() {
        let mut b = Breakdown::new();
        b.add("x", 1.0).add("y", 2.0).add("x", 0.5);
        assert!((b.total() - 3.5).abs() < 1e-12);
        assert!((b.get("x") - 1.5).abs() < 1e-12);
        assert_eq!(b.get("zzz"), 0.0);
    }

    #[test]
    fn collapse_sums_duplicates_in_order() {
        let mut b = Breakdown::new();
        b.add("x", 1.0).add("y", 2.0).add("x", 3.0);
        let c = b.collapsed();
        assert_eq!(c.segments.len(), 2);
        assert_eq!(c.segments[0], ("x".to_string(), 4.0));
    }

    #[test]
    fn absorb_merges() {
        let mut a = Breakdown::new();
        a.add("x", 1.0);
        let mut b = Breakdown::new();
        b.add("x", 2.0).add("y", 3.0);
        a.absorb(&b);
        assert_eq!(a.get("x"), 3.0);
        assert_eq!(a.get("y"), 3.0);
    }

    #[test]
    fn parallel_advances_by_max() {
        let mut c = SimClock::new();
        c.parallel(("gpu", 2.0), ("cpu", 5.0));
        assert_eq!(c.now, 5.0);
        // busy time recorded per device
        assert_eq!(c.breakdown.get("gpu"), 2.0);
        assert_eq!(c.breakdown.get("cpu"), 5.0);
        // wall = busy_total + overlap_saved
        assert!((c.breakdown.total() - c.now).abs() < 1e-12);
    }

    #[test]
    fn serial_advance() {
        let mut c = SimClock::new();
        c.advance("a", 1.5);
        c.advance("b", 0.5);
        assert_eq!(c.now, 2.0);
    }
}
