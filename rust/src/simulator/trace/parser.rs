//! Recursive-descent scenario parser (zero dependencies, hand rolled).
//!
//! Grammar (full EBNF in docs/SCENARIOS.md):
//!
//! ```text
//! scenario   := "scenario" IDENT "{" statement* "}"
//! statement  := "seed" INT | "requests" INT | "batch" INT
//!             | "kv_slots" INT | "queue_bound" INT | "watermark" INT
//!             | "arrival" arrival | "prompt" dist | "gen" dist
//!             | "share_prefix" "(" "groups" "=" INT "," "len" "=" INT ")"
//!             | "turns" "(" "per_session" "=" INT "," "grow" "=" INT ")"
//!             | "deadline_ms" dist | "cancel" fault | "disconnect" fault
//!             | "stream" PROB
//! arrival    := "fixed" "(" "interval" "=" INT ")"
//!             | "bursty" "(" "period" "=" INT "," "size" "=" INT ")"
//!             | "phases" "(" INT ":" arrival ("," INT ":" arrival)* ")"
//! dist       := "fixed" "(" INT ")"
//!             | "uniform" "(" INT "," INT ")"
//!             | "choice" "(" INT ("," INT)* ")"
//! fault      := PROB "after" dist
//! ```
//!
//! Statements may appear in any order but at most once each; `arrival`,
//! `prompt`, and `gen` are required. Every rejection — lexical, syntactic,
//! or semantic (range checks) — is a spanned [`ParseError`]; the parser
//! never panics on any input (pinned by the ≥1000-seed fuzz property in
//! `tests/integration_trace.rs`).

use super::ast::{Arrival, Dist, Fault, Scenario};
use super::lexer::{lex, ParseError, Span, Tok};

/// Hard ceilings keeping a parsed scenario replayable in CI: they bound
/// trace size and per-request work, so a scenario that parses is one the
/// harness can actually run (docs/SCENARIOS.md lists them).
pub const MAX_REQUESTS: u64 = 100_000;
pub const MAX_BATCH: u64 = 64;
pub const MAX_PROMPT_BYTES: u64 = 4096;
pub const MAX_GEN_TOKENS: u64 = 100_000;

/// Parse canonical or free-form scenario text into a validated
/// [`Scenario`].
pub fn parse(src: &str) -> Result<Scenario, ParseError> {
    Parser::new(src)?.scenario()
}

struct Parser {
    toks: Vec<(Tok, Span)>,
    pos: usize,
}

impl Parser {
    fn new(src: &str) -> Result<Parser, ParseError> {
        Ok(Parser {
            toks: lex(src)?,
            pos: 0,
        })
    }

    fn peek(&self) -> &(Tok, Span) {
        // the token stream always ends with Eof; clamp so a deep error
        // path can never index past it
        &self.toks[self.pos.min(self.toks.len() - 1)]
    }

    fn next(&mut self) -> (Tok, Span) {
        let t = self.peek().clone();
        if self.pos < self.toks.len() - 1 {
            self.pos += 1;
        }
        t
    }

    fn expect(&mut self, want: &Tok, what: &str) -> Result<Span, ParseError> {
        let (tok, span) = self.next();
        if &tok == want {
            Ok(span)
        } else {
            Err(ParseError::at(span, format!("expected {what}, found {tok}")))
        }
    }

    fn ident(&mut self, what: &str) -> Result<(String, Span), ParseError> {
        let (tok, span) = self.next();
        match tok {
            Tok::Ident(s) => Ok((s, span)),
            other => Err(ParseError::at(span, format!("expected {what}, found {other}"))),
        }
    }

    fn number(&mut self, what: &str) -> Result<(f64, Span), ParseError> {
        let (tok, span) = self.next();
        match tok {
            Tok::Num(n) => Ok((n, span)),
            other => Err(ParseError::at(span, format!("expected {what}, found {other}"))),
        }
    }

    /// A non-negative integer in `lo..=hi`; fractional values are errors
    /// (no silent truncation).
    fn int(&mut self, what: &str, lo: u64, hi: u64) -> Result<u64, ParseError> {
        let (n, span) = self.number(what)?;
        if n.fract() != 0.0 || n < 0.0 || n > u64::MAX as f64 {
            return Err(ParseError::at(
                span,
                format!("{what} must be an integer, got {n}"),
            ));
        }
        let v = n as u64;
        if v < lo || v > hi {
            return Err(ParseError::at(
                span,
                format!("{what} must be in {lo}..={hi}, got {v}"),
            ));
        }
        Ok(v)
    }

    /// A probability in `[0, 1]`.
    fn prob(&mut self, what: &str) -> Result<f64, ParseError> {
        let (n, span) = self.number(what)?;
        if !(0.0..=1.0).contains(&n) {
            return Err(ParseError::at(
                span,
                format!("{what} must be a probability in [0, 1], got {n}"),
            ));
        }
        Ok(n)
    }

    fn dist(&mut self, what: &str, lo: u64, hi: u64) -> Result<Dist, ParseError> {
        let (kind, span) = self.ident(&format!("a distribution for {what}"))?;
        self.expect(&Tok::LParen, "'('")?;
        let d = match kind.as_str() {
            "fixed" => {
                let v = self.int(what, lo, hi)?;
                Dist::Fixed(v)
            }
            "uniform" => {
                let a = self.int(what, lo, hi)?;
                self.expect(&Tok::Comma, "','")?;
                let b = self.int(what, lo, hi)?;
                if a > b {
                    return Err(ParseError::at(
                        span,
                        format!("uniform bounds for {what} are reversed ({a} > {b})"),
                    ));
                }
                Dist::Uniform(a, b)
            }
            "choice" => {
                let mut vs = vec![self.int(what, lo, hi)?];
                while self.peek().0 == Tok::Comma {
                    self.next();
                    vs.push(self.int(what, lo, hi)?);
                }
                Dist::Choice(vs)
            }
            other => {
                return Err(ParseError::at(
                    span,
                    format!("unknown distribution '{other}' (expected fixed, uniform, or choice)"),
                ));
            }
        };
        self.expect(&Tok::RParen, "')'")?;
        Ok(d)
    }

    fn arrival(&mut self, nested: bool) -> Result<Arrival, ParseError> {
        let (kind, span) = self.ident("an arrival process")?;
        match kind.as_str() {
            "fixed" => {
                self.expect(&Tok::LParen, "'('")?;
                let (key, kspan) = self.ident("'interval'")?;
                if key != "interval" {
                    return Err(ParseError::at(
                        kspan,
                        format!("expected 'interval', found '{key}'"),
                    ));
                }
                self.expect(&Tok::Eq, "'='")?;
                let interval = self.int("interval", 1, 1_000_000)?;
                self.expect(&Tok::RParen, "')'")?;
                Ok(Arrival::Fixed { interval })
            }
            "bursty" => {
                self.expect(&Tok::LParen, "'('")?;
                let (key, kspan) = self.ident("'period'")?;
                if key != "period" {
                    return Err(ParseError::at(kspan, format!("expected 'period', found '{key}'")));
                }
                self.expect(&Tok::Eq, "'='")?;
                let period = self.int("period", 1, 1_000_000)?;
                self.expect(&Tok::Comma, "','")?;
                let (key, kspan) = self.ident("'size'")?;
                if key != "size" {
                    return Err(ParseError::at(kspan, format!("expected 'size', found '{key}'")));
                }
                self.expect(&Tok::Eq, "'='")?;
                let size = self.int("size", 1, 10_000)?;
                self.expect(&Tok::RParen, "')'")?;
                Ok(Arrival::Bursty { period, size })
            }
            "phases" => {
                if nested {
                    return Err(ParseError::at(span, "phases cannot nest"));
                }
                self.expect(&Tok::LParen, "'('")?;
                let mut phases = Vec::new();
                loop {
                    let ticks = self.int("phase length (ticks)", 1, 1_000_000)?;
                    self.expect(&Tok::Colon, "':' after the phase length")?;
                    let sub = self.arrival(true)?;
                    phases.push((ticks, sub));
                    match self.next() {
                        (Tok::Comma, _) => continue,
                        (Tok::RParen, _) => break,
                        (tok, span) => {
                            return Err(ParseError::at(
                                span,
                                format!("expected ',' or ')' in phases, found {tok}"),
                            ));
                        }
                    }
                }
                Ok(Arrival::Phases(phases))
            }
            other => Err(ParseError::at(
                span,
                format!("unknown arrival process '{other}' (expected fixed, bursty, or phases)"),
            )),
        }
    }

    /// `( k1 = INT , k2 = INT )` — the two-key paren form shared by
    /// `share_prefix` and `turns` (same shape as `bursty`).
    #[allow(clippy::too_many_arguments)]
    fn pair(
        &mut self,
        k1: &str,
        lo1: u64,
        hi1: u64,
        k2: &str,
        lo2: u64,
        hi2: u64,
    ) -> Result<(u64, u64), ParseError> {
        self.expect(&Tok::LParen, "'('")?;
        let (key, kspan) = self.ident(&format!("'{k1}'"))?;
        if key != k1 {
            return Err(ParseError::at(kspan, format!("expected '{k1}', found '{key}'")));
        }
        self.expect(&Tok::Eq, "'='")?;
        let a = self.int(k1, lo1, hi1)?;
        self.expect(&Tok::Comma, "','")?;
        let (key, kspan) = self.ident(&format!("'{k2}'"))?;
        if key != k2 {
            return Err(ParseError::at(kspan, format!("expected '{k2}', found '{key}'")));
        }
        self.expect(&Tok::Eq, "'='")?;
        let b = self.int(k2, lo2, hi2)?;
        self.expect(&Tok::RParen, "')'")?;
        Ok((a, b))
    }

    fn fault(&mut self, what: &str) -> Result<Fault, ParseError> {
        let prob = self.prob(&format!("{what} probability"))?;
        let (kw, span) = self.ident("'after'")?;
        if kw != "after" {
            return Err(ParseError::at(span, format!("expected 'after', found '{kw}'")));
        }
        let after = self.dist(&format!("{what} delay"), 0, 1_000_000)?;
        Ok(Fault { prob, after })
    }

    fn scenario(&mut self) -> Result<Scenario, ParseError> {
        let (kw, span) = self.ident("'scenario'")?;
        if kw != "scenario" {
            return Err(ParseError::at(span, format!("expected 'scenario', found '{kw}'")));
        }
        let (name, _) = self.ident("a scenario name")?;
        self.expect(&Tok::LBrace, "'{'")?;

        let mut seed: Option<u64> = None;
        let mut requests: Option<u64> = None;
        let mut batch: Option<u64> = None;
        let mut kv_slots: Option<u64> = None;
        let mut queue_bound: Option<u64> = None;
        let mut watermark: Option<u64> = None;
        let mut arrival: Option<Arrival> = None;
        let mut prompt: Option<Dist> = None;
        let mut gen: Option<Dist> = None;
        let mut share_prefix: Option<(u64, u64)> = None;
        let mut turns: Option<(u64, u64)> = None;
        let mut deadline_ms: Option<Dist> = None;
        let mut cancel: Option<Fault> = None;
        let mut disconnect: Option<Fault> = None;
        let mut stream: Option<f64> = None;

        loop {
            let (tok, span) = self.next();
            let key = match tok {
                Tok::RBrace => break,
                Tok::Ident(s) => s,
                other => {
                    return Err(ParseError::at(
                        span,
                        format!("expected a statement or '}}', found {other}"),
                    ));
                }
            };
            // duplicate statements are ambiguous (which wins?) — reject
            // with the span of the second occurrence
            macro_rules! once {
                ($slot:ident, $value:expr) => {{
                    if $slot.is_some() {
                        return Err(ParseError::at(span, format!("duplicate statement '{key}'")));
                    }
                    $slot = Some($value);
                }};
            }
            match key.as_str() {
                "seed" => once!(seed, self.int("seed", 0, u64::MAX)?),
                "requests" => once!(requests, self.int("requests", 1, MAX_REQUESTS)?),
                "batch" => once!(batch, self.int("batch", 1, MAX_BATCH)?),
                "kv_slots" => once!(kv_slots, self.int("kv_slots", 1, 10_000)?),
                "queue_bound" => once!(queue_bound, self.int("queue_bound", 0, 1_000_000)?),
                "watermark" => once!(watermark, self.int("watermark", 1, 1_000_000)?),
                "arrival" => once!(arrival, self.arrival(false)?),
                "prompt" => once!(prompt, self.dist("prompt bytes", 1, MAX_PROMPT_BYTES)?),
                "gen" => once!(gen, self.dist("gen tokens", 0, MAX_GEN_TOKENS)?),
                "share_prefix" => once!(
                    share_prefix,
                    self.pair("groups", 1, 10_000, "len", 1, MAX_PROMPT_BYTES)?
                ),
                "turns" => once!(turns, {
                    let (t, g) = self.pair("per_session", 1, 10_000, "grow", 1, MAX_PROMPT_BYTES)?;
                    if t.saturating_mul(g) > MAX_PROMPT_BYTES {
                        return Err(ParseError::at(
                            span,
                            format!(
                                "turns: per_session × grow is the largest turn prompt and must \
                                 be ≤ {MAX_PROMPT_BYTES}, got {}",
                                t.saturating_mul(g)
                            ),
                        ));
                    }
                    (t, g)
                }),
                "deadline_ms" => {
                    once!(deadline_ms, self.dist("deadline_ms", 1, 86_400_000)?)
                }
                "cancel" => once!(cancel, self.fault("cancel")?),
                "disconnect" => once!(disconnect, self.fault("disconnect")?),
                "stream" => once!(stream, self.prob("stream fraction")?),
                other => {
                    return Err(ParseError::at(
                        span,
                        format!(
                            "unknown statement '{other}' (expected one of seed, requests, \
                             batch, kv_slots, queue_bound, watermark, arrival, prompt, gen, \
                             share_prefix, turns, deadline_ms, cancel, disconnect, stream)"
                        ),
                    ));
                }
            }
        }
        let (tok, span) = self.next();
        if tok != Tok::Eof {
            return Err(ParseError::at(
                span,
                format!("expected end of input after '}}', found {tok}"),
            ));
        }

        let require = |name: &str, missing: bool| -> Result<(), ParseError> {
            if missing {
                Err(ParseError::at(span, format!("missing required statement '{name}'")))
            } else {
                Ok(())
            }
        };
        require("arrival", arrival.is_none())?;
        require("prompt", prompt.is_none())?;
        require("gen", gen.is_none())?;
        if share_prefix.is_some() && turns.is_some() {
            return Err(ParseError::at(
                span,
                "share_prefix and turns cannot combine (pick one prompt structure)",
            ));
        }

        Ok(Scenario {
            name,
            seed: seed.unwrap_or(1),
            requests: requests.unwrap_or(16) as usize,
            batch: batch.unwrap_or(4) as usize,
            kv_slots: kv_slots.map(|v| v as usize),
            queue_bound,
            watermark: watermark.map(|v| v as usize),
            arrival: arrival.expect("checked above"),
            prompt: prompt.expect("checked above"),
            gen: gen.expect("checked above"),
            share_prefix,
            turns,
            deadline_ms,
            cancel,
            disconnect,
            stream: stream.unwrap_or(0.0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal() -> &'static str {
        "scenario s {\n  arrival fixed(interval=2)\n  prompt uniform(8, 16)\n  gen fixed(4)\n}\n"
    }

    #[test]
    fn minimal_parses_with_defaults() {
        let s = parse(minimal()).unwrap();
        assert_eq!(s.name, "s");
        assert_eq!((s.seed, s.requests, s.batch), (1, 16, 4));
        assert_eq!(s.arrival, Arrival::Fixed { interval: 2 });
        assert_eq!(s.stream, 0.0);
        assert!(s.kv_slots.is_none() && s.deadline_ms.is_none());
    }

    #[test]
    fn canonical_format_reparses_to_the_same_ast() {
        let s = parse(minimal()).unwrap();
        let text = s.to_string();
        assert_eq!(parse(&text).unwrap(), s);
        assert_eq!(parse(&text).unwrap().to_string(), text);
    }

    #[test]
    fn full_statement_set_round_trips() {
        let src = "scenario full {
  seed 9
  requests 5
  batch 2
  kv_slots 3
  queue_bound 40
  watermark 12
  arrival phases(10: fixed(interval=1), 20: bursty(period=5, size=3))
  prompt choice(8, 16, 32)
  gen uniform(2, 6)
  share_prefix(groups=3, len=32)
  deadline_ms uniform(30000, 60000)
  cancel 0.25 after uniform(1, 4)
  disconnect 0.5 after fixed(2)
  stream 0.75
}
";
        let s = parse(src).unwrap();
        assert_eq!(s.to_string(), src);
    }

    #[test]
    fn turns_round_trips_and_prefix_structures_are_exclusive() {
        let src = "scenario t {
  arrival fixed(interval=1)
  prompt fixed(8)
  gen fixed(2)
  turns(per_session=4, grow=16)
  stream 0
}
";
        let s = parse(src).unwrap();
        assert_eq!(s.turns, Some((4, 16)));
        assert_eq!(s.to_string(), src);

        let e = parse(
            "scenario t {\n  arrival fixed(interval=1)\n  prompt fixed(8)\n  gen fixed(2)\n  \
             share_prefix(groups=2, len=8)\n  turns(per_session=2, grow=8)\n}",
        )
        .unwrap_err();
        assert!(e.msg.contains("cannot combine"), "{e}");

        // per_session × grow bounds the largest turn prompt
        let e = parse(
            "scenario t {\n  arrival fixed(interval=1)\n  prompt fixed(8)\n  gen fixed(2)\n  \
             turns(per_session=100, grow=100)\n}",
        )
        .unwrap_err();
        assert!(e.msg.contains("largest turn prompt"), "{e}");

        // the two-key form rejects wrong key names with a span
        let e = parse(
            "scenario t {\n  arrival fixed(interval=1)\n  prompt fixed(8)\n  gen fixed(2)\n  \
             share_prefix(count=2, len=8)\n}",
        )
        .unwrap_err();
        assert!(e.msg.contains("expected 'groups'"), "{e}");
    }

    #[test]
    fn duplicate_statement_is_spanned() {
        let e = parse("scenario s {\n  seed 1\n  seed 2\n}").unwrap_err();
        assert_eq!((e.line, e.col), (3, 3));
        assert!(e.msg.contains("duplicate statement 'seed'"));
    }

    #[test]
    fn nested_phases_rejected() {
        let e = parse(
            "scenario s {\n  arrival phases(5: phases(2: fixed(interval=1)))\n  prompt fixed(8)\n  gen fixed(1)\n}",
        )
        .unwrap_err();
        assert!(e.msg.contains("phases cannot nest"), "{e}");
        assert_eq!(e.line, 2);
    }

    #[test]
    fn semantic_range_errors_are_spanned() {
        for (src, needle) in [
            ("scenario s {\n  stream 1.5\n}", "probability"),
            ("scenario s {\n  prompt uniform(9, 3)\n}", "reversed"),
            ("scenario s {\n  requests 2.5\n}", "integer"),
            ("scenario s {\n  batch 0\n}", "must be in 1..="),
            ("scenario s {\n  prompt fixed(0)\n}", "must be in 1..="),
            ("scenario s {\n  frobnicate 3\n}", "unknown statement"),
        ] {
            let e = parse(src).unwrap_err();
            assert!(e.msg.contains(needle), "for {src:?}: {e}");
            assert!(e.line >= 1 && e.col >= 1);
        }
    }

    #[test]
    fn missing_required_statement() {
        let e = parse("scenario s {\n  arrival fixed(interval=1)\n  gen fixed(1)\n}").unwrap_err();
        assert!(e.msg.contains("missing required statement 'prompt'"));
    }

    #[test]
    fn truncated_inputs_error_cleanly() {
        for src in [
            "",
            "scenario",
            "scenario s",
            "scenario s {",
            "scenario s { arrival fixed(interval=",
            "scenario s { arrival bursty(period=3, ",
        ] {
            let e = parse(src).unwrap_err();
            assert!(e.line >= 1 && e.col >= 1, "for {src:?}");
        }
    }

    #[test]
    fn trailing_garbage_rejected() {
        let e = parse(&format!("{} extra", parse(minimal()).unwrap())).unwrap_err();
        assert!(e.msg.contains("after '}'"), "{e}");
    }
}
