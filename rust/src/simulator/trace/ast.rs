//! Scenario AST and its canonical textual form.
//!
//! The formatter is the *definition* of canonical scenario text: statements
//! in a fixed order, one per line, two-space indent, `None` optionals
//! omitted. The parser accepts statements in any order, so for every value
//! the grammar can express, `format → parse → format` is a fixed point
//! (pinned by the round-trip property test in `tests/integration_trace.rs`).

use std::fmt;

/// A parsed workload scenario: the shape of an offered request trace plus
/// the serving knobs (batch width, KV slots, admission bounds) it runs
/// against. Field semantics are documented in docs/SCENARIOS.md.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Scenario name (an identifier; used as the report/gate key).
    pub name: String,
    /// Default sampler seed; `hgca replay --seed` overrides it.
    pub seed: u64,
    /// Total number of requests the trace generates.
    pub requests: usize,
    /// Batch rows the replay batcher runs with.
    pub batch: usize,
    /// Whole-sequence GPU KV lease slots (`None` = one slot per batch
    /// row, i.e. KV never binds before row count does).
    pub kv_slots: Option<usize>,
    /// Max ticks a request may wait in the admission queue before it is
    /// shed (`None` = wait forever).
    pub queue_bound: Option<u64>,
    /// Admission watermark applied at submit time (`None` = never shed
    /// on queue depth).
    pub watermark: Option<usize>,
    /// Arrival process generating request ticks.
    pub arrival: Arrival,
    /// Distribution of prompt lengths in bytes (values ≥ 1).
    pub prompt: Dist,
    /// Distribution of `max_new_tokens`.
    pub gen: Dist,
    /// Shared-prefix structure: `(groups, len)` partitions the trace into
    /// `groups` families whose prompts open with the same `len`-byte
    /// prefix (`None` = fully independent prompts). Replay auto-enables
    /// the prefix cache when set.
    pub share_prefix: Option<(u64, u64)>,
    /// Multi-turn structure: `(per_session, grow)` folds consecutive
    /// requests into sessions of `per_session` turns; each turn re-sends
    /// the session transcript plus `grow` fresh bytes (`None` = every
    /// request is a fresh conversation). Replay auto-enables the prefix
    /// cache when set.
    pub turns: Option<(u64, u64)>,
    /// Distribution of per-request deadlines in milliseconds (`None` =
    /// no deadlines).
    pub deadline_ms: Option<Dist>,
    /// Client-cancel fault injection (`None` = no cancels).
    pub cancel: Option<Fault>,
    /// Client-disconnect fault injection (`None` = no disconnects).
    pub disconnect: Option<Fault>,
    /// Probability a request is streamed (token events counted per
    /// request) rather than buffered.
    pub stream: f64,
}

/// When requests arrive, on the batcher tick clock.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// One request every `interval` ticks.
    Fixed { interval: u64 },
    /// `size` requests at once, every `period` ticks.
    Bursty { period: u64, size: u64 },
    /// Diurnal phases: each `(ticks, arrival)` window runs its
    /// sub-process for `ticks` ticks, then the next phase starts; the
    /// list cycles until the trace has generated all requests. Phases
    /// cannot nest.
    Phases(Vec<(u64, Arrival)>),
}

/// A small integer distribution (prompt bytes, generation lengths,
/// deadline milliseconds, fault delays).
#[derive(Debug, Clone, PartialEq)]
pub enum Dist {
    /// Always `n`.
    Fixed(u64),
    /// Uniform over `lo..=hi` (inclusive; `lo ≤ hi`).
    Uniform(u64, u64),
    /// Uniform over an explicit non-empty value list.
    Choice(Vec<u64>),
}

impl Dist {
    /// Smallest value the distribution can produce.
    pub fn min(&self) -> u64 {
        match self {
            Dist::Fixed(n) => *n,
            Dist::Uniform(lo, _) => *lo,
            Dist::Choice(vs) => vs.iter().copied().min().unwrap_or(0),
        }
    }

    /// Largest value the distribution can produce.
    pub fn max(&self) -> u64 {
        match self {
            Dist::Fixed(n) => *n,
            Dist::Uniform(_, hi) => *hi,
            Dist::Choice(vs) => vs.iter().copied().max().unwrap_or(0),
        }
    }
}

/// A fault-injection knob: with probability `prob`, the request trips its
/// cancel token `after` ticks past its arrival.
#[derive(Debug, Clone, PartialEq)]
pub struct Fault {
    /// Per-request trigger probability in `[0, 1]`.
    pub prob: f64,
    /// Delay distribution (ticks after arrival).
    pub after: Dist,
}

impl fmt::Display for Dist {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dist::Fixed(n) => write!(f, "fixed({n})"),
            Dist::Uniform(lo, hi) => write!(f, "uniform({lo}, {hi})"),
            Dist::Choice(vs) => {
                write!(f, "choice(")?;
                for (i, v) in vs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, ")")
            }
        }
    }
}

impl fmt::Display for Arrival {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Arrival::Fixed { interval } => write!(f, "fixed(interval={interval})"),
            Arrival::Bursty { period, size } => {
                write!(f, "bursty(period={period}, size={size})")
            }
            Arrival::Phases(phases) => {
                write!(f, "phases(")?;
                for (i, (ticks, sub)) in phases.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{ticks}: {sub}")?;
                }
                write!(f, ")")
            }
        }
    }
}

impl fmt::Display for Fault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} after {}", self.prob, self.after)
    }
}

impl fmt::Display for Scenario {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "scenario {} {{", self.name)?;
        writeln!(f, "  seed {}", self.seed)?;
        writeln!(f, "  requests {}", self.requests)?;
        writeln!(f, "  batch {}", self.batch)?;
        if let Some(s) = self.kv_slots {
            writeln!(f, "  kv_slots {s}")?;
        }
        if let Some(q) = self.queue_bound {
            writeln!(f, "  queue_bound {q}")?;
        }
        if let Some(w) = self.watermark {
            writeln!(f, "  watermark {w}")?;
        }
        writeln!(f, "  arrival {}", self.arrival)?;
        writeln!(f, "  prompt {}", self.prompt)?;
        writeln!(f, "  gen {}", self.gen)?;
        if let Some((g, l)) = self.share_prefix {
            writeln!(f, "  share_prefix(groups={g}, len={l})")?;
        }
        if let Some((t, l)) = self.turns {
            writeln!(f, "  turns(per_session={t}, grow={l})")?;
        }
        if let Some(d) = &self.deadline_ms {
            writeln!(f, "  deadline_ms {d}")?;
        }
        if let Some(c) = &self.cancel {
            writeln!(f, "  cancel {c}")?;
        }
        if let Some(d) = &self.disconnect {
            writeln!(f, "  disconnect {d}")?;
        }
        writeln!(f, "  stream {}", self.stream)?;
        writeln!(f, "}}")
    }
}
