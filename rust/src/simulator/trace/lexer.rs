//! Hand-rolled scenario lexer: identifiers, decimal numbers, punctuation,
//! `#` line comments, with 1-based line/column spans on every token so the
//! parser can report *where* an input went wrong.

use std::fmt;

/// 1-based source position of a token (or of an error).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub line: usize,
    pub col: usize,
}

/// A lexed token. Numbers carry the `f64` value std parsed from the
/// lexeme — the parser range-checks it and rejects fractional values
/// where an integer is required ("1.5 requests" is an error, not a
/// truncation).
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// `[A-Za-z_][A-Za-z0-9_]*`
    Ident(String),
    /// Decimal literal: optional fraction and exponent, no sign (the
    /// grammar has no negative quantities).
    Num(f64),
    LBrace,
    RBrace,
    LParen,
    RParen,
    Comma,
    Eq,
    Colon,
    /// End of input (always the final token of a successful lex).
    Eof,
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Ident(s) => write!(f, "'{s}'"),
            Tok::Num(n) => write!(f, "number {n}"),
            Tok::LBrace => write!(f, "'{{'"),
            Tok::RBrace => write!(f, "'}}'"),
            Tok::LParen => write!(f, "'('"),
            Tok::RParen => write!(f, "')'"),
            Tok::Comma => write!(f, "','"),
            Tok::Eq => write!(f, "'='"),
            Tok::Colon => write!(f, "':'"),
            Tok::Eof => write!(f, "end of input"),
        }
    }
}

/// A spanned lexical or syntactic error. `Display` renders
/// `line L, col C: message` — the format tests assert on.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

impl ParseError {
    pub fn at(span: Span, msg: impl Into<String>) -> ParseError {
        ParseError {
            line: span.line,
            col: span.col,
            msg: msg.into(),
        }
    }
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}, col {}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Tokenize `src`, returning spanned tokens ending with [`Tok::Eof`].
/// Invalid characters and malformed numbers are spanned errors, never
/// panics — the lexer walks `char_indices` so arbitrary (even non-UTF-8
/// lossy-decoded) input is safe to feed it.
pub fn lex(src: &str) -> Result<Vec<(Tok, Span)>, ParseError> {
    let mut out = Vec::new();
    let mut line = 1usize;
    let mut col = 1usize;
    let mut chars = src.chars().peekable();
    while let Some(&c) = chars.peek() {
        let span = Span { line, col };
        match c {
            '\n' => {
                chars.next();
                line += 1;
                col = 1;
            }
            c if c.is_whitespace() => {
                chars.next();
                col += 1;
            }
            '#' => {
                // comment to end of line (the newline itself is handled
                // by the '\n' arm next iteration)
                while let Some(&c) = chars.peek() {
                    if c == '\n' {
                        break;
                    }
                    chars.next();
                    col += 1;
                }
            }
            '{' | '}' | '(' | ')' | ',' | '=' | ':' => {
                chars.next();
                col += 1;
                out.push((
                    match c {
                        '{' => Tok::LBrace,
                        '}' => Tok::RBrace,
                        '(' => Tok::LParen,
                        ')' => Tok::RParen,
                        ',' => Tok::Comma,
                        '=' => Tok::Eq,
                        _ => Tok::Colon,
                    },
                    span,
                ));
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let mut s = String::new();
                while let Some(&c) = chars.peek() {
                    if c.is_ascii_alphanumeric() || c == '_' {
                        s.push(c);
                        chars.next();
                        col += 1;
                    } else {
                        break;
                    }
                }
                out.push((Tok::Ident(s), span));
            }
            c if c.is_ascii_digit() || c == '.' => {
                let mut s = String::new();
                let mut saw_exp = false;
                while let Some(&c) = chars.peek() {
                    let take = c.is_ascii_digit()
                        || c == '.'
                        || c == 'e'
                        || c == 'E'
                        // a sign is part of the number only right after
                        // the exponent marker (there are no signed
                        // literals elsewhere in the grammar)
                        || ((c == '+' || c == '-')
                            && saw_exp
                            && matches!(s.chars().last(), Some('e' | 'E')));
                    if !take {
                        break;
                    }
                    if c == 'e' || c == 'E' {
                        saw_exp = true;
                    }
                    s.push(c);
                    chars.next();
                    col += 1;
                }
                let n: f64 = s
                    .parse()
                    .map_err(|_| ParseError::at(span, format!("malformed number '{s}'")))?;
                if !n.is_finite() {
                    return Err(ParseError::at(span, format!("number '{s}' out of range")));
                }
                out.push((Tok::Num(n), span));
            }
            other => {
                return Err(ParseError::at(
                    span,
                    format!("unexpected character '{}'", other.escape_default()),
                ));
            }
        }
    }
    out.push((Tok::Eof, Span { line, col }));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_are_one_based_and_track_lines() {
        let toks = lex("scenario x {\n  seed 7\n}\n").unwrap();
        assert_eq!(toks[0], (Tok::Ident("scenario".into()), Span { line: 1, col: 1 }));
        assert_eq!(toks[3].1, Span { line: 2, col: 3 }); // `seed`
        assert_eq!(toks[4], (Tok::Num(7.0), Span { line: 2, col: 8 }));
        assert_eq!(toks[5].1, Span { line: 3, col: 1 }); // `}`
        assert_eq!(toks.last().unwrap().0, Tok::Eof);
    }

    #[test]
    fn comments_and_floats() {
        let toks = lex("stream 0.25 # half\nbatch 2e1").unwrap();
        assert_eq!(toks[1].0, Tok::Num(0.25));
        assert_eq!(toks[3].0, Tok::Num(20.0));
    }

    #[test]
    fn bad_char_is_spanned() {
        let e = lex("seed 1\n  @").unwrap_err();
        assert_eq!((e.line, e.col), (2, 3));
        assert!(e.msg.contains("unexpected character"));
    }

    #[test]
    fn malformed_number_is_an_error_not_a_panic() {
        let e = lex("seed 1..2e").unwrap_err();
        assert_eq!((e.line, e.col), (1, 6));
        assert!(e.msg.contains("malformed number"));
    }
}
