//! Seeded trace sampler: expands a parsed [`Scenario`] into a concrete
//! request list. Same `(scenario, seed)` → bitwise-identical trace, on any
//! machine: the only entropy source is the [`Lcg`] below (the
//! `util/corpus.rs` generator, same constants), prompts are slices of the
//! deterministic synthetic corpus, and arrival ticks are computed, not
//! drawn — so the arrival process never perturbs the per-request draw
//! stream.

use super::ast::{Arrival, Dist, Fault, Scenario};
use crate::util::corpus;

/// Deterministic PRNG, same multiplier/increment as the corpus generator
/// (`util/corpus.rs::Lcg`, itself mirroring python/compile/corpus.py).
/// Public so the property tests can drive AST/fuzz generation from the
/// exact generator the sampler uses.
#[derive(Debug, Clone)]
pub struct Lcg {
    state: u64,
}

impl Lcg {
    pub fn new(seed: u64) -> Lcg {
        Lcg { state: seed }
    }

    pub fn next(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.state >> 33
    }

    /// Uniform integer in `lo..=hi` (inclusive; `lo ≤ hi`).
    pub fn randint(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }

    /// Uniform fraction in `[0, 1)` with 1e-6 resolution — enough for the
    /// grammar's probability knobs while keeping the draw integral (no
    /// float-rounding divergence across platforms).
    pub fn frac(&mut self) -> f64 {
        (self.next() % 1_000_000) as f64 / 1_000_000.0
    }
}

impl Dist {
    /// Draw one value. `Fixed` consumes no randomness — a constant knob
    /// must not shift the draw stream of the knobs after it.
    pub fn sample(&self, rng: &mut Lcg) -> u64 {
        match self {
            Dist::Fixed(n) => *n,
            Dist::Uniform(lo, hi) => rng.randint(*lo, *hi),
            Dist::Choice(vs) => vs[(rng.next() as usize) % vs.len()],
        }
    }
}

/// One concrete request of a sampled trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceRequest {
    /// 1-based request id (submission order).
    pub id: u64,
    /// Batcher tick at which the request is offered.
    pub arrive_tick: u64,
    /// Prompt bytes (a slice of the deterministic synthetic corpus).
    pub prompt: Vec<u8>,
    /// Generation budget (`max_new_tokens`).
    pub max_new_tokens: usize,
    /// Deadline in milliseconds after replay start, if assigned.
    pub deadline_ms: Option<u64>,
    /// Ticks after arrival at which the client cancels, if it does.
    pub cancel_after: Option<u64>,
    /// Ticks after arrival at which the client disconnects, if it does.
    pub disconnect_after: Option<u64>,
    /// Whether the client streams (token events are counted per token).
    pub stream: bool,
}

/// Arrival ticks for the first `n` requests of an arrival process.
/// Computed in closed form (phases by walking the cycle), so the arrival
/// shape never consumes sampler randomness.
pub fn arrival_ticks(arrival: &Arrival, n: usize) -> Vec<u64> {
    match arrival {
        Arrival::Fixed { interval } => (0..n as u64).map(|i| i * interval).collect(),
        Arrival::Bursty { period, size } => {
            (0..n as u64).map(|i| (i / size) * period).collect()
        }
        Arrival::Phases(phases) => {
            let mut out = Vec::with_capacity(n);
            let mut base = 0u64; // tick at which the current phase starts
            let mut idx = 0usize;
            while out.len() < n {
                let (ticks, sub) = &phases[idx % phases.len()];
                // generate the sub-process locally, keep arrivals that
                // land inside this phase's window
                let window = *ticks;
                let local = arrival_ticks(sub, n - out.len());
                for t in local {
                    if t < window && out.len() < n {
                        out.push(base + t);
                    }
                }
                base += window;
                idx += 1;
            }
            out
        }
    }
}

fn fault_draw(fault: &Option<Fault>, rng: &mut Lcg) -> Option<u64> {
    let f = fault.as_ref()?;
    // draw the trigger even when prob is 0 or 1 so toggling a fault's
    // probability, not its presence, is what changes the stream
    let hit = rng.frac() < f.prob;
    hit.then(|| f.after.sample(rng))
}

/// Expand `scn` into its concrete request trace using `seed` (callers pass
/// `scn.seed` unless overridden on the CLI). Per request the draw order is
/// fixed — prompt length, prompt offset, gen, deadline, stream, cancel,
/// disconnect — so adding a knob to a scenario changes only that knob's
/// draws.
pub fn sample_trace(scn: &Scenario, seed: u64) -> Vec<TraceRequest> {
    let mut corpus_len = 65_536.max(scn.prompt.max() as usize + 1);
    // prefix structures slice extra corpus regions: every group prefix
    // (share_prefix) and every session transcript (turns) must fit
    if let Some((g, l)) = scn.share_prefix {
        corpus_len = corpus_len.max((g as usize) * (l as usize) + 1);
    }
    if let Some((t, grow)) = scn.turns {
        corpus_len = corpus_len.max((t as usize) * (grow as usize) + 1);
    }
    let corpus = corpus::generate(corpus_len, seed);
    let mut rng = Lcg::new(seed);
    let ticks = arrival_ticks(&scn.arrival, scn.requests);
    let mut out = Vec::with_capacity(scn.requests);
    for (i, arrive_tick) in ticks.into_iter().enumerate() {
        // the base draws always happen — prompt structure must not shift
        // the draw stream of the knobs after it (deadline, faults, …)
        let prompt_len = scn.prompt.sample(&mut rng) as usize;
        let offset = rng.randint(0, (corpus.len() - prompt_len) as u64) as usize;
        let mut prompt = corpus[offset..offset + prompt_len].to_vec();
        if let Some((groups, len)) = scn.share_prefix {
            // request i belongs to group i % groups; the group prefix is
            // a computed corpus slice (no draws), overwriting the front
            // of the sampled prompt
            let g = (i as u64 % groups) as usize;
            let l = (len as usize).min(prompt.len());
            let at = g * len as usize;
            prompt[..l].copy_from_slice(&corpus[at..at + l]);
        }
        if let Some((per_session, grow)) = scn.turns {
            // consecutive requests fold into sessions; turn t re-sends
            // the transcript so far plus `grow` fresh bytes, all from a
            // per-session corpus region picked arithmetically (no draws)
            let session = i as u64 / per_session;
            let turn = i as u64 % per_session;
            let max_len = (per_session * grow) as usize;
            let wrap = (corpus.len() - max_len).max(1);
            let base = (session.wrapping_mul(8191) as usize) % wrap;
            let len = ((turn + 1) * grow) as usize;
            prompt = corpus[base..base + len].to_vec();
        }
        let max_new_tokens = scn.gen.sample(&mut rng) as usize;
        let deadline_ms = scn.deadline_ms.as_ref().map(|d| d.sample(&mut rng));
        let stream = rng.frac() < scn.stream;
        let cancel_after = fault_draw(&scn.cancel, &mut rng);
        let disconnect_after = fault_draw(&scn.disconnect, &mut rng);
        out.push(TraceRequest {
            id: i as u64 + 1,
            arrive_tick,
            prompt,
            max_new_tokens,
            deadline_ms,
            cancel_after,
            disconnect_after,
            stream,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::trace::parse;

    #[test]
    fn lcg_matches_corpus_constants() {
        // first outputs of the corpus LCG from seed 1 (pinned so the two
        // implementations cannot drift apart silently)
        let mut r = Lcg::new(1);
        let mut s = 1u64;
        for _ in 0..4 {
            s = s
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            assert_eq!(r.next(), s >> 33);
        }
    }

    #[test]
    fn arrival_shapes() {
        assert_eq!(
            arrival_ticks(&Arrival::Fixed { interval: 3 }, 4),
            vec![0, 3, 6, 9]
        );
        assert_eq!(
            arrival_ticks(&Arrival::Bursty { period: 10, size: 2 }, 5),
            vec![0, 0, 10, 10, 20]
        );
        // phase 1: interval 2 over 5 ticks -> local 0,2,4 ; phase 2:
        // burst of 2 at its start (tick 5); cycle back to phase 1
        let ph = Arrival::Phases(vec![
            (5, Arrival::Fixed { interval: 2 }),
            (3, Arrival::Bursty { period: 10, size: 2 }),
        ]);
        assert_eq!(arrival_ticks(&ph, 6), vec![0, 2, 4, 5, 5, 8]);
    }

    #[test]
    fn same_seed_same_trace_different_seed_differs() {
        let scn = parse(
            "scenario s {\n  requests 8\n  arrival fixed(interval=2)\n  prompt uniform(8, 64)\n  gen uniform(2, 6)\n  cancel 0.5 after uniform(1, 5)\n  stream 0.5\n}",
        )
        .unwrap();
        let a = sample_trace(&scn, 7);
        let b = sample_trace(&scn, 7);
        assert_eq!(a, b);
        let c = sample_trace(&scn, 8);
        assert_ne!(a, c);
        assert!(a.iter().all(|r| (8..=64).contains(&r.prompt.len())));
    }

    #[test]
    fn share_prefix_groups_share_bytes_and_shift_no_draws() {
        let base = "scenario s {\n  requests 6\n  arrival fixed(interval=1)\n  prompt uniform(32, 64)\n  gen uniform(2, 6)\nSTRUCT  stream 0.5\n}";
        let plain = parse(&base.replace("STRUCT", "")).unwrap();
        let shared =
            parse(&base.replace("STRUCT", "  share_prefix(groups=2, len=16)\n")).unwrap();
        let tp = sample_trace(&plain, 11);
        let ts = sample_trace(&shared, 11);
        // group structure: requests 0,2,4 share one 16-byte prefix,
        // 1,3,5 another, and the two differ
        assert_eq!(ts[0].prompt[..16], ts[2].prompt[..16]);
        assert_eq!(ts[2].prompt[..16], ts[4].prompt[..16]);
        assert_eq!(ts[1].prompt[..16], ts[3].prompt[..16]);
        assert_ne!(ts[0].prompt[..16], ts[1].prompt[..16]);
        // zero new draws: everything except the prompt bytes matches the
        // structure-free trace exactly
        for (p, s) in tp.iter().zip(&ts) {
            assert_eq!(p.prompt.len(), s.prompt.len());
            assert_eq!(p.max_new_tokens, s.max_new_tokens);
            assert_eq!(p.stream, s.stream);
            assert_eq!(p.prompt[16..], s.prompt[16..], "only the prefix is rewritten");
        }
    }

    #[test]
    fn turns_build_prefix_nested_session_transcripts() {
        let scn = parse(
            "scenario t {\n  requests 8\n  arrival fixed(interval=1)\n  prompt fixed(8)\n  gen fixed(2)\n  turns(per_session=4, grow=16)\n}",
        )
        .unwrap();
        let t = sample_trace(&scn, 5);
        // within a session every turn extends the previous transcript
        for s in 0..2usize {
            for turn in 0..4usize {
                let r = &t[s * 4 + turn];
                assert_eq!(r.prompt.len(), (turn + 1) * 16);
                if turn > 0 {
                    let prev = &t[s * 4 + turn - 1];
                    assert_eq!(r.prompt[..prev.prompt.len()], prev.prompt[..]);
                }
            }
        }
        // distinct sessions draw from distinct corpus regions
        assert_ne!(t[0].prompt, t[4].prompt);
    }

    #[test]
    fn fixed_dists_consume_no_randomness() {
        // two scenarios identical except one turns a sampled knob into a
        // fixed one: the draws *after* it must not shift
        let base = "scenario s {\n  requests 4\n  arrival fixed(interval=1)\n  prompt fixed(16)\n  gen GEN\n  stream 0.5\n}";
        let a = parse(&base.replace("GEN", "fixed(4)")).unwrap();
        let b = parse(&base.replace("GEN", "fixed(9)")).unwrap();
        let ta = sample_trace(&a, 3);
        let tb = sample_trace(&b, 3);
        for (x, y) in ta.iter().zip(&tb) {
            assert_eq!(x.stream, y.stream);
            assert_eq!(x.prompt, y.prompt);
        }
    }
}
