//! Trace-driven workload harness: a compact scenario DSL plus an
//! in-process replay driver (docs/SCENARIOS.md is the user-facing
//! reference).
//!
//! * [`lexer`] / [`parser`] / [`ast`] — hand-rolled recursive-descent
//!   front end: scenario text → validated [`Scenario`], every rejection a
//!   spanned [`ParseError`].
//! * [`sampler`] — seeded-LCG expansion of a scenario into a concrete
//!   request trace, bitwise-reproducible from `(scenario, seed)`.
//! * [`mod@replay`] — runs the trace against the real serving stack
//!   (batcher + lifecycle + KV pool + NUMA placement) and aggregates a
//!   gate-ready [`ReplayReport`].
//!
//! Exercised by `hgca replay`, the CI `scenario-replay` gate
//! (`tools/scenario_gate.rs`), and the `integration_trace` /
//! `integration_replay` test suites.

pub mod ast;
pub mod lexer;
pub mod parser;
pub mod replay;
pub mod sampler;

pub use ast::{Arrival, Dist, Fault, Scenario};
pub use lexer::ParseError;
pub use parser::parse;
pub use replay::{replay, ReplayOptions, ReplayReport, RequestOutcome};
pub use sampler::{arrival_ticks, sample_trace, Lcg, TraceRequest};
