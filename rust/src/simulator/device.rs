//! Device cost model: roofline execution-time estimates for compute devices.
//!
//! The paper's performance results depend on A6000 / Xeon-6430 / PCIe-4.0
//! hardware we do not have (repro band 0); per DESIGN.md §1 we replace the
//! hardware with an analytic roofline model — the exact model the paper's own
//! Figure 1 reasons with — parameterized by published peak FLOPS and memory
//! bandwidth. All simulated results are labeled `sim` in bench output.

/// A compute device with a two-ceiling roofline.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: String,
    /// Peak dense FLOP/s at the serving precision (fp16 for GPU presets).
    pub peak_flops: f64,
    /// Peak memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Fixed per-kernel launch overhead, seconds.
    pub launch_overhead: f64,
}

impl DeviceSpec {
    /// NVIDIA RTX A6000 (paper §1: 38.7 TFLOPS fp16, 768 GB/s GDDR6).
    pub fn a6000() -> DeviceSpec {
        DeviceSpec {
            name: "a6000".into(),
            peak_flops: 38.7e12,
            mem_bw: 768e9,
            launch_overhead: 8e-6,
        }
    }

    /// Intel Xeon Gold 6430 socket (paper §1: 1.229 TFLOPS fp16 AMX;
    /// 8×DDR5-4400 ≈ 280 GB/s per socket as configured in the paper's
    /// testbed — the 500 GB/s figure in §1 assumes 32 fully-populated slots).
    pub fn xeon6430() -> DeviceSpec {
        DeviceSpec {
            name: "xeon6430".into(),
            peak_flops: 1.229e12,
            mem_bw: 280e9,
            launch_overhead: 2e-6,
        }
    }

    /// Roofline time for an op with the given work. The `efficiency`
    /// de-rates peak (attention kernels don't hit peak; 0 < e <= 1).
    pub fn op_time(&self, flops: f64, bytes: f64, efficiency: f64) -> f64 {
        assert!(efficiency > 0.0 && efficiency <= 1.0);
        let compute = flops / (self.peak_flops * efficiency);
        let memory = bytes / self.mem_bw;
        self.launch_overhead + compute.max(memory)
    }

    /// Operational intensity (FLOP/byte) at which this device transitions
    /// from memory-bound to compute-bound (the roofline knee).
    pub fn ridge_intensity(&self) -> f64 {
        self.peak_flops / self.mem_bw
    }

    /// Attainable FLOP/s at a given operational intensity (Fig. 1's roof).
    pub fn attainable_flops(&self, intensity: f64) -> f64 {
        (intensity * self.mem_bw).min(self.peak_flops)
    }
}

/// Work characterization of one attention call (the paper's decode/append
/// taxonomy, §2). All sizes in elements; bytes_per_el is the KV precision.
#[derive(Debug, Clone, Copy)]
pub struct AttnWork {
    pub batch: usize,
    pub heads: usize,
    pub d_head: usize,
    /// queries per sequence (1 = decode, >1 = append/prefill)
    pub n_query: usize,
    /// KV entries attended per sequence
    pub n_kv: usize,
    pub bytes_per_el: usize,
}

impl AttnWork {
    /// 2·B·H·N·N'·dh for QKᵀ plus the same for P·V.
    pub fn flops(&self) -> f64 {
        4.0 * self.batch as f64
            * self.heads as f64
            * self.n_query as f64
            * self.n_kv as f64
            * self.d_head as f64
    }

    /// Dominant traffic: K and V streamed once; Q/O are N·dh (small).
    pub fn bytes(&self) -> f64 {
        let kv = 2.0 * self.batch as f64 * self.heads as f64 * self.n_kv as f64 * self.d_head as f64;
        let qo = 2.0 * self.batch as f64 * self.heads as f64 * self.n_query as f64 * self.d_head as f64;
        (kv + qo) * self.bytes_per_el as f64
    }

    pub fn kv_bytes(&self) -> f64 {
        2.0 * self.batch as f64
            * self.heads as f64
            * self.n_kv as f64
            * self.d_head as f64
            * self.bytes_per_el as f64
    }

    pub fn intensity(&self) -> f64 {
        self.flops() / self.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decode_work(n_kv: usize) -> AttnWork {
        AttnWork {
            batch: 1,
            heads: 32,
            d_head: 128,
            n_query: 1,
            n_kv,
            bytes_per_el: 2,
        }
    }

    #[test]
    fn decode_is_memory_bound_on_gpu() {
        // paper Fig. 1: decode sits far left of the GPU ridge
        let w = decode_work(4096);
        let gpu = DeviceSpec::a6000();
        assert!(w.intensity() < gpu.ridge_intensity());
        // memory term must dominate
        let t = gpu.op_time(w.flops(), w.bytes(), 1.0) - gpu.launch_overhead;
        let mem_t = w.bytes() / gpu.mem_bw;
        assert!((t - mem_t).abs() / mem_t < 1e-9);
    }

    #[test]
    fn prefill_is_compute_bound_on_gpu() {
        // 1:1 query:kv ratio with long sequences → right of the ridge
        let w = AttnWork {
            batch: 8,
            heads: 32,
            d_head: 128,
            n_query: 2048,
            n_kv: 2048,
            bytes_per_el: 2,
        };
        assert!(w.intensity() > DeviceSpec::a6000().ridge_intensity());
    }

    #[test]
    fn cpu_gpu_bandwidth_gap_is_narrow() {
        // paper's core motivation: TFLOPS gap ≥ 10×, bandwidth gap < 3×
        let gpu = DeviceSpec::a6000();
        let cpu = DeviceSpec::xeon6430();
        assert!(gpu.peak_flops / cpu.peak_flops > 10.0);
        assert!(gpu.mem_bw / cpu.mem_bw < 3.0);
    }

    #[test]
    fn attainable_flops_clips_at_peak() {
        let gpu = DeviceSpec::a6000();
        let knee = gpu.ridge_intensity();
        assert!(gpu.attainable_flops(knee * 10.0) == gpu.peak_flops);
        assert!(gpu.attainable_flops(knee / 10.0) < gpu.peak_flops);
    }

    #[test]
    fn op_time_monotonic_in_work() {
        let gpu = DeviceSpec::a6000();
        let t1 = gpu.op_time(1e9, 1e6, 1.0);
        let t2 = gpu.op_time(2e9, 1e6, 1.0);
        assert!(t2 >= t1);
    }

    #[test]
    fn flops_bytes_formulas() {
        let w = decode_work(1000);
        // flops = 4 * 1 * 32 * 1 * 1000 * 128
        assert_eq!(w.flops(), 4.0 * 32.0 * 1000.0 * 128.0);
        // kv bytes = 2 * 32 * 1000 * 128 * 2
        assert_eq!(w.kv_bytes(), 2.0 * 32.0 * 1000.0 * 128.0 * 2.0);
    }
}
