//! Hardware simulation substrate (DESIGN.md §1): roofline device cost
//! models, interconnect transfer models, labeled time breakdowns, and the
//! attention-placement scenarios used by every performance bench — plus
//! the [`trace`] workload harness, which replays scenario-DSL traces
//! against the *real* serving stack rather than these cost models.

pub mod clock;
pub mod device;
pub mod interconnect;
pub mod scenarios;
pub mod trace;

pub use clock::{Breakdown, SimClock};
pub use device::{AttnWork, DeviceSpec};
pub use interconnect::Interconnect;
pub use scenarios::Testbed;
