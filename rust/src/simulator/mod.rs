//! Hardware simulation substrate (DESIGN.md §1): roofline device cost
//! models, interconnect transfer models, labeled time breakdowns, and the
//! attention-placement scenarios used by every performance bench.

pub mod clock;
pub mod device;
pub mod interconnect;
pub mod scenarios;

pub use clock::{Breakdown, SimClock};
pub use device::{AttnWork, DeviceSpec};
pub use interconnect::Interconnect;
pub use scenarios::Testbed;
