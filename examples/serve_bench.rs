//! END-TO-END VALIDATION DRIVER (DESIGN.md, EXPERIMENTS.md §E2E).
//!
//! Loads the trained tiny model, starts the HTTP server + continuous
//! batcher, floods it with concurrent client requests over real TCP, and
//! reports latency/throughput — the full serving stack in one run:
//! HTTP → batcher → engine → PJRT artifacts ("GPU") ∥ rust CPU sparse
//! attention → LSE merge → sampler.
//!
//! Run: cargo run --release --example serve_bench [-- --requests 24 --batch 4]

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use hgca::config::HgcaConfig;
use hgca::engine::batcher::{Batcher, Request};
use hgca::engine::{Engine, Policy};
use hgca::runtime::PjrtRuntime;
use hgca::util::argparse::Args;
use hgca::util::stats::summarize;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let n_requests = args.usize("requests", 24)?;
    let batch = args.usize("batch", 4)?;
    let max_new = args.usize("max-new", 24)?;

    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = Rc::new(PjrtRuntime::new(&dir)?);
    let mr = rt.load_model(args.get_or("model", "tiny"))?;
    mr.warn_if_synthetic();
    let n_arts = mr.warmup()?;
    println!("model {} warmed ({n_arts} artifacts compiled)", mr.cfg.name);

    // ---------------- phase 1: HTTP round-trip smoke ----------------
    let (tx, rx) = std::sync::mpsc::channel();
    let (addr, _h) = hgca::server::serve("127.0.0.1:0", tx)?;
    println!("http server on {addr}");
    let client = std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
        let mut lat = Vec::new();
        for i in 0..4 {
            let t0 = Instant::now();
            let mut s = TcpStream::connect(addr)?;
            let body = format!(
                r#"{{"prompt": "The garrison defended route {i} through ", "max_new_tokens": 16}}"#
            );
            write!(
                s,
                "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
                body.len(),
                body
            )?;
            let mut out = String::new();
            s.read_to_string(&mut out)?;
            anyhow::ensure!(out.starts_with("HTTP/1.1 200"), "bad response: {out}");
            lat.push(t0.elapsed().as_secs_f64());
        }
        Ok(lat)
    });
    {
        let cfg = HgcaConfig::default();
        let mut engine = Engine::new(&mr, cfg, Policy::Hgca { beta: 1.0 });
        // serve exactly the smoke requests then fall through
        let mut served = 0;
        for inc in &rx {
            let resp = hgca::server::api::handle_generate(&mut engine, &inc.req.body, served);
            let _ = inc.reply.send(hgca::server::ServerReply::Full(resp));
            served += 1;
            if served >= 4 {
                break;
            }
        }
    }
    let http_lat = client.join().expect("client thread")?;
    let hs = summarize(&http_lat);
    println!(
        "http smoke: 4 requests ok, latency p50 {:.1} ms, max {:.1} ms",
        hs.p50 * 1e3,
        hs.max * 1e3
    );

    // ---------------- phase 2: batched serving benchmark ----------------
    let cfg = HgcaConfig::default();
    let mut engine = Engine::new(&mr, cfg, Policy::Hgca { beta: 1.0 });
    let mut batcher = Batcher::new(batch);
    let prompts = [
        "The settlement was established near the Brazos River ",
        "Historical records show that the cattle drive ",
        "In the following decade, the territorial legislature ",
        "The trading post shipped grain from Galveston ",
    ];
    for i in 0..n_requests {
        batcher.submit(Request {
            id: i as u64,
            prompt: prompts[i % prompts.len()].as_bytes().to_vec(),
            max_new_tokens: max_new,
        });
    }
    let t0 = Instant::now();
    let done = batcher.run_to_completion(&mut engine)?;
    let wall = t0.elapsed().as_secs_f64();

    anyhow::ensure!(done.len() == n_requests, "lost requests");
    let total_tokens: usize = done.iter().map(|c| c.text.len()).sum();
    let m = &engine.metrics;
    let tbt = m.tbt_summary().unwrap();
    println!("\n=== serve_bench results (policy=hgca, batch={batch}) ===");
    println!("requests completed : {}", done.len());
    println!("tokens generated   : {total_tokens}");
    println!("wall time          : {wall:.2} s");
    println!("throughput         : {:.1} tok/s (wall)", total_tokens as f64 / wall);
    println!("sim throughput     : {:.1} tok/s (paper testbed model)", m.sim_throughput());
    println!(
        "TBT p50/p90/p99    : {:.1} / {:.1} / {:.1} ms",
        tbt.p50 * 1e3,
        tbt.p90 * 1e3,
        tbt.p99 * 1e3
    );
    println!(
        "peak kv memory     : gpu {} | cpu {}",
        hgca::util::fmt_bytes(m.peak_gpu_kv_bytes as u64),
        hgca::util::fmt_bytes(m.peak_cpu_kv_bytes as u64)
    );
    let st = mr.stats.borrow();
    println!(
        "pjrt: {} calls, exec {:.2}s, upload {:.2}s, download {:.2}s",
        st.calls, st.exec_secs, st.upload_secs, st.download_secs
    );
    println!("\nsample completion [{}]: {:?}", done[0].id, String::from_utf8_lossy(&done[0].text));
    Ok(())
}
