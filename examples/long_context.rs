//! Long-context decoding (paper §5.4, Fig. 15 scenario): continuous
//! decode far beyond the GPU window; the KV cache grows with sequence
//! length and hybrid attention keeps the GPU pool bounded.
//!
//! Run: cargo run --release --example long_context [-- --tokens 2048]
//! (paper runs 16,384; default here is sized for CI wall-clock)

use std::path::PathBuf;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::{Engine, Policy};
use hgca::runtime::PjrtRuntime;
use hgca::util::argparse::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let total = args.usize("tokens", 2048)?;
    let window = args.usize("window", 256)?;

    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = Rc::new(PjrtRuntime::new(&dir)?);
    let mr = rt.load_model(args.get_or("model", "tiny"))?;
    mr.warn_if_synthetic();
    let cfg = HgcaConfig::default().with_window(window);
    let mut engine = Engine::new(&mr, cfg, Policy::Hgca { beta: 1.0 });
    engine.sampler = hgca::model::Sampler::Temperature { t: 0.9, seed: 7 };

    let mut seq = engine.new_sequence(0, b"= Palo Duro Canyon =\n\n");
    println!("decoding {total} tokens (window {window}, beta 1.0)…");
    engine.generate(&mut seq, total)?;

    // token-rate curve in windows of 256 steps (Fig. 15 shape)
    let m = &engine.metrics;
    println!("\nposition   wall tok/s   sim tok/s   TBT p99 (ms, wall)");
    let chunk = 256;
    for (i, win) in m.tbt.chunks(chunk).enumerate() {
        let sim_win = &m.sim_tbt[i * chunk..(i * chunk + win.len()).min(m.sim_tbt.len())];
        let wall_rate = win.len() as f64 / win.iter().sum::<f64>();
        let sim_rate = sim_win.len() as f64 / sim_win.iter().sum::<f64>().max(1e-12);
        let s = hgca::util::stats::summarize(win);
        println!(
            "{:>8}   {:>10.1}   {:>9.1}   {:>8.2}",
            (i + 1) * chunk,
            wall_rate,
            sim_rate,
            s.p99 * 1e3
        );
    }
    println!(
        "\nfinal kv: window {} entries on gpu, {} on cpu ({} ctx-selected, {:.1}% mean selectivity)",
        seq.kv.window_len(0),
        seq.kv.layers[0].cpu.len(),
        seq.kv.layers[0].cpu.ctx_len_total(),
        seq.kv.mean_selectivity() * 100.0
    );
    println!(
        "peak gpu kv {} (bounded) | cpu kv {} (grows with context)",
        hgca::util::fmt_bytes(m.peak_gpu_kv_bytes as u64),
        hgca::util::fmt_bytes(m.peak_cpu_kv_bytes as u64)
    );
    Ok(())
}
