//! Quickstart: load the trained tiny model, generate text with HGCA
//! hybrid attention, print serving stats.
//!
//! Run: cargo run --release --example quickstart

use std::path::PathBuf;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::{Engine, Policy};
use hgca::runtime::PjrtRuntime;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(std::env::var("HGCA_ARTIFACTS").unwrap_or("artifacts".into()));
    let rt = Rc::new(PjrtRuntime::new(&dir)?);
    let mr = rt.load_model("tiny")?;
    mr.warn_if_synthetic();
    println!(
        "loaded {} ({} params) on {}",
        mr.cfg.name,
        mr.cfg.param_count(),
        rt.client.platform_name()
    );

    // HGCA config: 256-entry GPU window (8 blocks × 32), β = 1
    let cfg = HgcaConfig::default();
    let mut engine = Engine::new(&mr, cfg, Policy::Hgca { beta: 1.0 });

    let prompt = b"The railway company surveyed the region around ";
    let mut seq = engine.new_sequence(0, prompt);
    let out = engine.generate(&mut seq, 96)?;

    println!("--- prompt ---\n{}", String::from_utf8_lossy(prompt));
    println!("--- completion ---\n{}", String::from_utf8_lossy(&out));

    let m = &engine.metrics;
    println!("\n--- stats ---");
    println!("wall throughput : {:.1} tok/s", m.throughput());
    println!("sim  throughput : {:.1} tok/s (paper testbed model)", m.sim_throughput());
    println!(
        "gpu kv peak     : {}",
        hgca::util::fmt_bytes(m.peak_gpu_kv_bytes as u64)
    );
    println!(
        "cpu kv peak     : {}",
        hgca::util::fmt_bytes(m.peak_cpu_kv_bytes as u64)
    );
    println!(
        "mean per-head selectivity: {:.1}%",
        seq.kv.mean_selectivity() * 100.0
    );
    Ok(())
}
