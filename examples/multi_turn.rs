//! Multi-turn conversation: exercises the append path and the CPU-side
//! re-evaluation (paper §3.2.2 "Re-evaluation") — each new user turn
//! re-scores the offloaded KV entries and rebuilds the contextual cache.
//!
//! Run: cargo run --release --example multi_turn

use std::path::PathBuf;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::{Engine, Policy};
use hgca::runtime::PjrtRuntime;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(std::env::var("HGCA_ARTIFACTS").unwrap_or("artifacts".into()));
    let rt = Rc::new(PjrtRuntime::new(&dir)?);
    let mr = rt.load_model("tiny")?;
    mr.warn_if_synthetic();
    let cfg = HgcaConfig {
        blk_size: 16,
        blk_num: 4, // small 64-entry window so turns spill to the CPU store
        ..Default::default()
    };
    let mut engine = Engine::new(&mr, cfg, Policy::Hgca { beta: 1.0 });

    let turns: [&[u8]; 3] = [
        b"The expedition mapped the region around Palo Duro Canyon. ",
        b"Meanwhile, the railway company negotiated with Governor Coke. ",
        b"According to later historians, the settlement was established near ",
    ];

    let mut seq = engine.new_sequence(0, turns[0]);
    for (i, turn) in turns.iter().enumerate() {
        if i > 0 {
            seq.tokens.extend_from_slice(turn); // append the new user turn
        }
        engine.prefill(&mut seq)?;
        let reply = engine.generate(&mut seq, 32)?;
        println!("turn {}: …{}", i + 1, String::from_utf8_lossy(&reply));
        // show how the contextual cache adapted
        let l0 = &seq.kv.layers[0].cpu;
        let sel: Vec<String> = l0
            .selectivity()
            .iter()
            .map(|s| format!("{:.0}%", s * 100.0))
            .collect();
        println!(
            "  cpu store: {} entries; per-head ctx selectivity: [{}]",
            l0.len(),
            sel.join(", ")
        );
    }
    Ok(())
}
