//! Accuracy sweep (Table 1 scenario): teacher-forced perplexity of HGCA
//! hybrid attention vs full attention across β × GPU-KV-ratio, on the
//! trained model and the bundled corpus.
//!
//! Run: cargo run --release --example accuracy_sweep [-- --len 256]

use std::path::PathBuf;
use std::rc::Rc;

use hgca::config::HgcaConfig;
use hgca::engine::{Engine, Policy};
use hgca::runtime::PjrtRuntime;
use hgca::util::argparse::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let len = args.usize("len", 256)?;
    let model = args.get_or("model", "tiny-small").to_string();

    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = Rc::new(PjrtRuntime::new(&dir)?);
    let mr = rt.load_model(&model)?;
    mr.warn_if_synthetic();
    let text = hgca::util::corpus::ensure_corpus(std::path::Path::new(
        args.get_or("text", "data/corpus.txt"),
    ))?;
    let text = &text[1000..1000 + len];

    // reference: full attention (exact) through the same engine
    let mk_cfg = |window: usize| HgcaConfig {
        blk_size: 8,
        blk_num: window / 8,
        ..Default::default()
    };
    let mut full = Engine::new(&mr, mk_cfg(32), Policy::FullOffload);
    let baseline = full.perplexity(text, 32)?;
    println!("model={model} len={len}  baseline (full attention) PPL = {baseline:.4}\n");

    println!("{:>10} {:>8} {:>10} {:>10} {:>12}", "gpu-ratio", "beta", "ppl", "Δ vs full", "ctx kept");
    for ratio in [0.25f64, 0.5, 0.75] {
        let window = (((len as f64 * ratio) / 8.0).ceil() as usize).max(1) * 8;
        for beta in [0.25f32, 0.5, 0.75, 1.0] {
            let mut cfg = mk_cfg(window);
            cfg.beta = beta;
            let mut engine = Engine::new(&mr, cfg, Policy::Hgca { beta });
            let ppl = engine.perplexity(text, 32)?;
            // measure retention on a fresh prefill
            let mut seq = engine.new_sequence(1, text);
            engine.prefill(&mut seq)?;
            let sel = seq.kv.mean_selectivity();
            println!(
                "{:>10.2} {:>8.2} {:>10.4} {:>+9.2}% {:>11.1}%",
                ratio,
                beta,
                ppl,
                (ppl / baseline - 1.0) * 100.0,
                sel * 100.0
            );
        }
    }
    Ok(())
}
