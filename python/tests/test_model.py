"""L2 model semantics: the split decode/prefill path (attn_step + rust-side
merge + post_attn) must reproduce the monolithic causal forward, for every
stage pattern the engine uses (decode N=1, prefill chunks, window eviction
handled by masking)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.configs import TINY_SMALL, ModelConfig
from compile.kernels import ref

CFG = TINY_SMALL


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(7))


def _decode_all(cfg, params, toks, W):
    """Run the split path token-by-token with everything in-window."""
    B, T = toks.shape
    H, dh, D = cfg.n_heads, cfg.d_head, cfg.d_model
    k_win = [jnp.zeros((B, H, W, dh)) for _ in range(cfg.n_layers)]
    v_win = [jnp.zeros((B, H, W, dh)) for _ in range(cfg.n_layers)]
    outs = []
    for t in range(T):
        hid = M.embed(toks[:, t:t + 1], jnp.full((B, 1), t, jnp.int32),
                      params.tok_emb, params.pos_emb)
        for li, lp in enumerate(params.layers):
            q, k_new, v_new, o, lse, a_sum = M.attn_step(
                cfg, hid, lp.ln1_g, lp.ln1_b, lp.wq, lp.bq, lp.wk, lp.bk,
                lp.wv, lp.bv, k_win[li], v_win[li], jnp.full((B,), t, jnp.int32),
                jnp.full((B,), 1, jnp.int32))
            k_win[li] = k_win[li].at[:, :, t].set(k_new[:, :, 0])
            v_win[li] = v_win[li].at[:, :, t].set(v_new[:, :, 0])
            o_flat = o.transpose(0, 2, 1, 3).reshape(B, 1, D)
            hid = M.post_attn(hid, o_flat, lp.wo, lp.bo, lp.ln2_g, lp.ln2_b,
                              lp.w1, lp.b1, lp.w2, lp.b2)
        outs.append(M.lm_head(hid, params.lnf_g, params.lnf_b, params.tok_emb))
    return jnp.concatenate(outs, axis=1)


def test_incremental_decode_matches_full(params):
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 10), 0, 255)
    full = M.full_forward(CFG, params, toks)
    inc = _decode_all(CFG, params, toks, W=16)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full), rtol=2e-4, atol=2e-4)


def test_prefill_chunk_matches_full(params):
    """One attn_step call with N=chunk must equal per-token decode."""
    B, T, W = 1, 8, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, 255)
    full = M.full_forward(CFG, params, toks)

    H, dh, D = CFG.n_heads, CFG.d_head, CFG.d_model
    k_win = [jnp.zeros((B, H, W, dh)) for _ in range(CFG.n_layers)]
    v_win = [jnp.zeros((B, H, W, dh)) for _ in range(CFG.n_layers)]
    hid = M.embed(toks, jnp.arange(T)[None, :], params.tok_emb, params.pos_emb)
    for li, lp in enumerate(params.layers):
        q, k_new, v_new, o, lse, a_sum = M.attn_step(
            CFG, hid, lp.ln1_g, lp.ln1_b, lp.wq, lp.bq, lp.wk, lp.bk,
            lp.wv, lp.bv, k_win[li], v_win[li], jnp.zeros((B,), jnp.int32),
            jnp.full((B,), T, jnp.int32))
        o_flat = o.transpose(0, 2, 1, 3).reshape(B, T, D)
        hid = M.post_attn(hid, o_flat, lp.wo, lp.bo, lp.ln2_g, lp.ln2_b,
                          lp.w1, lp.b1, lp.w2, lp.b2)
    logits = M.lm_head(hid, params.lnf_g, params.lnf_b, params.tok_emb)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full), rtol=2e-4, atol=2e-4)


def test_attn_step_asum_is_probability_mass(params):
    """a_sum must sum to N over valid slots per (b, h) — softmax rows sum to 1."""
    B, N, W = 2, 4, 12
    H, dh = CFG.n_heads, CFG.d_head
    rng = np.random.default_rng(0)
    hid = jnp.asarray(rng.normal(size=(B, N, CFG.d_model)), jnp.float32)
    k_win = jnp.asarray(rng.normal(size=(B, H, W, dh)), jnp.float32)
    v_win = jnp.asarray(rng.normal(size=(B, H, W, dh)), jnp.float32)
    lp = params.layers[0]
    win_len = jnp.array([5, 12], jnp.int32)
    *_, a_sum = M.attn_step(CFG, hid, lp.ln1_g, lp.ln1_b, lp.wq, lp.bq,
                            lp.wk, lp.bk, lp.wv, lp.bv, k_win, v_win, win_len,
                            jnp.full((B,), N, jnp.int32))
    total = np.asarray(jnp.sum(a_sum, axis=-1))  # [B,H]
    np.testing.assert_allclose(total, N, rtol=1e-4)
    # masked window slots get ~0 mass
    a = np.asarray(a_sum)
    assert np.all(a[0, :, 5:W] < 1e-6)


def test_attn_step_win_len_masks_stale_slots(params):
    """Garbage beyond win_len must not affect the output."""
    B, N, W = 1, 1, 8
    H, dh = CFG.n_heads, CFG.d_head
    rng = np.random.default_rng(1)
    hid = jnp.asarray(rng.normal(size=(B, N, CFG.d_model)), jnp.float32)
    k_win = jnp.asarray(rng.normal(size=(B, H, W, dh)), jnp.float32)
    v_win = jnp.asarray(rng.normal(size=(B, H, W, dh)), jnp.float32)
    lp = params.layers[0]
    wl = jnp.array([3], jnp.int32)
    nv = jnp.full((1,), N, jnp.int32)
    out1 = M.attn_step(CFG, hid, lp.ln1_g, lp.ln1_b, lp.wq, lp.bq, lp.wk,
                       lp.bk, lp.wv, lp.bv, k_win, v_win, wl, nv)
    k2 = k_win.at[:, :, 3:].set(999.0)
    v2 = v_win.at[:, :, 3:].set(-999.0)
    out2 = M.attn_step(CFG, hid, lp.ln1_g, lp.ln1_b, lp.wq, lp.bq, lp.wk,
                       lp.bk, lp.wv, lp.bv, k2, v2, wl, nv)
    np.testing.assert_allclose(np.asarray(out1[3]), np.asarray(out2[3]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out1[4]), np.asarray(out2[4]), atol=1e-6)


def test_hybrid_split_window_plus_cpu_side(params):
    """The actual HGCA dataflow: window holds only the recent tokens, the
    older KVs live 'on the CPU'; dense window attention merged with CPU
    attention over the evicted entries must equal full attention."""
    B, T, W = 1, 10, 4  # window holds 4 most-recent
    H, dh, D = CFG.n_heads, CFG.d_head, CFG.d_model
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, 255)
    full = M.full_forward(CFG, params, toks)

    # caches per layer: full K/V history (the "CPU" store) + rolling window
    hist_k = [[] for _ in range(CFG.n_layers)]
    hist_v = [[] for _ in range(CFG.n_layers)]
    outs = []
    for t in range(T):
        hid = M.embed(toks[:, t:t + 1], jnp.full((B, 1), t, jnp.int32),
                      params.tok_emb, params.pos_emb)
        for li, lp in enumerate(params.layers):
            n_cpu = max(0, t - W)            # evicted entries
            n_win = t - n_cpu                # in-window entries
            if n_win > 0:
                k_w = jnp.stack(hist_k[li][n_cpu:], axis=2)
                v_w = jnp.stack(hist_v[li][n_cpu:], axis=2)
                pad = W - n_win
                k_w = jnp.pad(k_w, ((0, 0), (0, 0), (0, pad), (0, 0)))
                v_w = jnp.pad(v_w, ((0, 0), (0, 0), (0, pad), (0, 0)))
            else:
                k_w = jnp.zeros((B, H, W, dh))
                v_w = jnp.zeros((B, H, W, dh))
            q, k_new, v_new, o_g, lse_g, _ = M.attn_step(
                CFG, hid, lp.ln1_g, lp.ln1_b, lp.wq, lp.bq, lp.wk, lp.bk,
                lp.wv, lp.bv, k_w, v_w, jnp.full((B,), n_win, jnp.int32),
                jnp.full((B,), 1, jnp.int32))
            if n_cpu > 0:  # "CPU" dense attention over evicted KVs + merge
                k_c = jnp.stack(hist_k[li][:n_cpu], axis=2)
                v_c = jnp.stack(hist_v[li][:n_cpu], axis=2)
                o_c, lse_c = ref.attention_with_lse(
                    q, k_c, v_c, jnp.zeros((B, 1, n_cpu), jnp.float32))
                o_g, lse_g = ref.merge_lse(o_c, lse_c, o_g, lse_g)
            hist_k[li].append(k_new[:, :, 0])
            hist_v[li].append(v_new[:, :, 0])
            o_flat = o_g.transpose(0, 2, 1, 3).reshape(B, 1, D)
            hid = M.post_attn(hid, o_flat, lp.wo, lp.bo, lp.ln2_g, lp.ln2_b,
                              lp.w1, lp.b1, lp.w2, lp.b2)
        outs.append(M.lm_head(hid, params.lnf_g, params.lnf_b, params.tok_emb))
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full), rtol=3e-4, atol=3e-4)


def test_param_count_matches_config():
    p = M.init_params(CFG, jax.random.PRNGKey(0))
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p))
    assert n == CFG.param_count()


def test_gelu_matches_reference_constants():
    # rust mirrors these exact constants; pin them
    x = jnp.linspace(-4, 4, 17)
    y = M.gelu(x)
    expected = 0.5 * x * (1 + np.tanh(np.sqrt(2 / np.pi) * (np.asarray(x) + 0.044715 * np.asarray(x) ** 3)))
    np.testing.assert_allclose(np.asarray(y), expected, rtol=1e-5, atol=1e-6)


def test_attn_step_padded_queries_are_inert(params):
    """n_valid masking: padded query rows must not contribute to a_sum and
    the valid rows' outputs must match an unpadded call (the §Perf padded-
    chunk prefill path relies on this)."""
    B, W = 1, 8
    H, dh = CFG.n_heads, CFG.d_head
    rng = np.random.default_rng(5)
    lp = params.layers[0]
    k_win = jnp.asarray(rng.normal(size=(B, H, W, dh)), jnp.float32)
    v_win = jnp.asarray(rng.normal(size=(B, H, W, dh)), jnp.float32)
    wl = jnp.array([W], jnp.int32)

    n_real, n_pad = 3, 8  # 3 valid queries padded to a chunk of 8
    hid_real = jnp.asarray(rng.normal(size=(B, n_real, CFG.d_model)), jnp.float32)
    hid_padded = jnp.concatenate(
        [hid_real, jnp.asarray(rng.normal(size=(B, n_pad - n_real, CFG.d_model)), jnp.float32)],
        axis=1,
    )
    out_ref = M.attn_step(CFG, hid_real, lp.ln1_g, lp.ln1_b, lp.wq, lp.bq,
                          lp.wk, lp.bk, lp.wv, lp.bv, k_win, v_win, wl,
                          jnp.array([n_real], jnp.int32))
    out_pad = M.attn_step(CFG, hid_padded, lp.ln1_g, lp.ln1_b, lp.wq, lp.bq,
                          lp.wk, lp.bk, lp.wv, lp.bv, k_win, v_win, wl,
                          jnp.array([n_real], jnp.int32))
    # valid query rows identical
    np.testing.assert_allclose(np.asarray(out_pad[3])[:, :, :n_real],
                               np.asarray(out_ref[3]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out_pad[4])[:, :, :n_real],
                               np.asarray(out_ref[4]), rtol=1e-5, atol=1e-5)
    # a_sum over window slots matches (padded rows contribute nothing there)
    np.testing.assert_allclose(np.asarray(out_pad[5])[:, :, :W],
                               np.asarray(out_ref[5])[:, :, :W], rtol=1e-4, atol=1e-5)
    # total attention mass equals the number of VALID queries only
    total = np.asarray(jnp.sum(out_pad[5], axis=-1))
    np.testing.assert_allclose(total, n_real, rtol=1e-4)


def test_attn_step_pallas_and_fused_paths_agree(params):
    """use_pallas=True (TPU-faithful) and use_pallas=False (CPU-serving
    artifact) must be numerically interchangeable."""
    B, N, W = 1, 4, 8
    H, dh = CFG.n_heads, CFG.d_head
    rng = np.random.default_rng(6)
    lp = params.layers[0]
    hid = jnp.asarray(rng.normal(size=(B, N, CFG.d_model)), jnp.float32)
    k_win = jnp.asarray(rng.normal(size=(B, H, W, dh)), jnp.float32)
    v_win = jnp.asarray(rng.normal(size=(B, H, W, dh)), jnp.float32)
    wl = jnp.array([5], jnp.int32)
    nv = jnp.array([N], jnp.int32)
    a = M.attn_step(CFG, hid, lp.ln1_g, lp.ln1_b, lp.wq, lp.bq, lp.wk, lp.bk,
                    lp.wv, lp.bv, k_win, v_win, wl, nv, use_pallas=True)
    b = M.attn_step(CFG, hid, lp.ln1_g, lp.ln1_b, lp.wq, lp.bq, lp.wk, lp.bk,
                    lp.wv, lp.bv, k_win, v_win, wl, nv, use_pallas=False)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-5)
