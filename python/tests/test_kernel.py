"""L1 correctness: pallas flash_window_attention vs the pure-jnp oracle.

Hypothesis sweeps shapes, masking patterns and scales; fixed cases pin the
regression corners (single query, fully-masked rows, non-divisible tiles).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.flash_window import flash_window_attention, vmem_footprint_bytes, NEG_INF
from compile.kernels import ref

RTOL, ATOL = 1e-5, 1e-5


def _mk(B, H, N, S, dh, seed=0, mask_p=0.0, scale=1.0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, H, N, dh)) * scale, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, dh)) * scale, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, dh)), jnp.float32)
    mask = rng.random((B, N, S)) < mask_p
    # never mask slot 0 so no row is fully masked (separate test covers that)
    mask[:, :, 0] = False
    bias = jnp.asarray(np.where(mask, NEG_INF, 0.0), jnp.float32)
    return q, k, v, bias


def _check(q, k, v, bias, block_q=64, block_k=128):
    o1, l1 = flash_window_attention(q, k, v, bias, block_q=block_q, block_k=block_k)
    o2, l2 = ref.attention_with_lse(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=RTOL, atol=ATOL)


# ---------------- fixed regression cases ----------------

def test_single_query_single_head():
    _check(*_mk(1, 1, 1, 16, 8))


def test_decode_shape_window_257():
    # W + 1 slot (decode appends one KV) — deliberately not tile-divisible
    _check(*_mk(2, 4, 1, 257, 32))


def test_prefill_chunk():
    _check(*_mk(2, 4, 64, 320, 32, mask_p=0.2))


def test_tile_exact_multiples():
    _check(*_mk(1, 2, 64, 256, 32))


def test_tile_non_multiples():
    _check(*_mk(1, 2, 17, 131, 32))


def test_small_blocks():
    _check(*_mk(1, 2, 30, 70, 16), block_q=8, block_k=16)


def test_large_scores_numerically_stable():
    q, k, v, bias = _mk(1, 2, 4, 64, 16, scale=30.0)
    _check(q, k, v, bias)


def test_fully_masked_row_is_finite_with_neg_inf_lse():
    # A fully-masked row never occurs on the engine path (a token always
    # attends to itself), but it must stay *finite* and carry lse ≈ -inf so
    # a downstream LSE merge assigns it ~zero weight.
    q, k, v, bias = _mk(1, 1, 2, 32, 8)
    bias = bias.at[0, 1, :].set(NEG_INF)
    o, lse = flash_window_attention(q, k, v, bias)
    assert np.all(np.isfinite(np.asarray(o)))
    assert float(lse[0, 0, 1]) < -1e29  # merge weight exp(lse - m) ≈ 0


def test_mask_prefix_equals_truncation():
    # masking the tail of the KVs must equal attention over the prefix only
    q, k, v, _ = _mk(1, 2, 3, 48, 16, seed=3)
    valid = 29
    bias = jnp.asarray(
        np.where(np.arange(48)[None, None, :] < valid, 0.0, NEG_INF), jnp.float32
    )
    bias = jnp.broadcast_to(bias, (1, 3, 48))
    o1, l1 = flash_window_attention(q, k, v, bias)
    o2, l2 = ref.attention_with_lse(q, k[:, :, :valid], v[:, :, :valid],
                                    jnp.zeros((1, 3, valid), jnp.float32))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=RTOL, atol=ATOL)


def test_vmem_footprint_within_budget():
    # DESIGN.md §6: default tiling must fit comfortably in 16 MiB VMEM
    assert vmem_footprint_bytes() < 2 * 1024 * 1024


# ---------------- hypothesis sweeps ----------------

@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 3),
    H=st.integers(1, 4),
    N=st.integers(1, 40),
    S=st.integers(1, 200),
    dh=st.sampled_from([4, 8, 16, 32]),
    mask_p=st.floats(0.0, 0.6),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shapes(B, H, N, S, dh, mask_p, seed):
    _check(*_mk(B, H, N, S, dh, seed=seed, mask_p=mask_p))


@settings(max_examples=10, deadline=None)
@given(
    block_q=st.sampled_from([8, 16, 64, 128]),
    block_k=st.sampled_from([8, 32, 128, 256]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_block_shapes(block_q, block_k, seed):
    # tiling must never change numerics
    q, k, v, bias = _mk(2, 2, 20, 150, 16, seed=seed, mask_p=0.3)
    _check(q, k, v, bias, block_q=block_q, block_k=block_k)


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(0.01, 50.0), seed=st.integers(0, 2**16))
def test_hypothesis_score_scales(scale, seed):
    _check(*_mk(1, 2, 8, 96, 16, seed=seed, scale=scale))
