"""AOT path: every entry point lowers to parseable HLO text with the input /
output arity the manifest promises (the rust runtime trusts this contract)."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.configs import TINY_SMALL, ArtifactShapes


SHAPE = ArtifactShapes(batch=1, window=32, chunk=8)


@pytest.fixture(scope="module")
def entries():
    return list(aot.build_entries(TINY_SMALL, SHAPE.batch, SHAPE.window, SHAPE.chunk))


def test_expected_entry_set(entries):
    kinds = sorted(e[0] for e in entries)
    assert kinds == sorted(["embed", "attn_step", "post_attn"] * 2 + ["lm_head"])


def test_all_entries_lower_to_hlo_text(entries):
    for kind, name, fn, args, out_names, out_shapes in entries:
        specs = [s for _, s in args]
        text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        assert text.startswith("HloModule"), f"{name}: no HloModule header"
        # param count must match declared inputs
        assert text.count("parameter(") >= len(args), name


def test_attn_entry_output_arity(entries):
    for kind, name, fn, args, out_names, out_shapes in entries:
        if kind != "attn_step":
            continue
        specs = [s for _, s in args]
        outs = jax.eval_shape(fn, *specs)
        assert len(outs) == 6 == len(out_names)
        for o, expect in zip(outs, out_shapes):
            assert list(o.shape) == list(expect), f"{name}: {o.shape} != {expect}"


def test_manifest_roundtrip(tmp_path, entries):
    manifest = []
    aot.lower_model(TINY_SMALL, [SHAPE], str(tmp_path), manifest, set())
    with open(tmp_path / "m.json", "w") as f:
        json.dump({"artifacts": manifest}, f)
    loaded = json.load(open(tmp_path / "m.json"))
    assert len(loaded["artifacts"]) == len(entries)
    for a in loaded["artifacts"]:
        assert os.path.exists(tmp_path / a["file"])
        assert a["model"] == "tiny-small"
        assert all(k in a for k in ("kind", "inputs", "outputs", "batch", "window", "chunk"))


def test_lowering_is_deterministic(entries):
    kind, name, fn, args, *_ = entries[0]
    specs = [s for _, s in args]
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert t1 == t2
