"""LSE-merge semantics (Algorithm 2 line 13): merging disjoint partial
attentions must equal one softmax over the union — the paper's 'lossless
aggregation' claim, which the rust coordinator relies on."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def _attn_parts(seed, B=2, H=2, N=3, S=40, dh=8, split=17, scale=1.0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, H, N, dh)) * scale, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, dh)), jnp.float32)
    z = jnp.zeros((B, N, S), jnp.float32)
    full = ref.attention_with_lse(q, k, v, z)
    a = ref.attention_with_lse(q, k[:, :, :split], v[:, :, :split], z[:, :, :split])
    b = ref.attention_with_lse(q, k[:, :, split:], v[:, :, split:], z[:, :, split:])
    return full, a, b


def test_merge_equals_union():
    (of, lf), (oa, la), (ob, lb) = _attn_parts(0)
    om, lm = ref.merge_lse(oa, la, ob, lb)
    np.testing.assert_allclose(np.asarray(om), np.asarray(of), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lm), np.asarray(lf), rtol=1e-5, atol=1e-5)


def test_merge_commutative():
    _, (oa, la), (ob, lb) = _attn_parts(1)
    o1, l1 = ref.merge_lse(oa, la, ob, lb)
    o2, l2 = ref.merge_lse(ob, lb, oa, la)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6, atol=1e-6)


def test_merge_with_empty_side_is_identity():
    # an empty domain has lse = -inf; merge must return the other side
    _, (oa, la), _ = _attn_parts(2)
    o_empty = jnp.zeros_like(oa)
    l_empty = jnp.full_like(la, -1e30)
    om, lm = ref.merge_lse(oa, la, o_empty, l_empty)
    np.testing.assert_allclose(np.asarray(om), np.asarray(oa), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(lm), np.asarray(la), rtol=1e-5, atol=1e-5)


def test_merge_associative_three_way():
    rng = np.random.default_rng(3)
    B, H, N, S, dh = 1, 2, 2, 60, 8
    q = jnp.asarray(rng.normal(size=(B, H, N, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, dh)), jnp.float32)
    z = jnp.zeros((B, N, S), jnp.float32)
    of, lf = ref.attention_with_lse(q, k, v, z)
    parts = [(0, 20), (20, 45), (45, 60)]
    os_, ls_ = [], []
    for s0, s1 in parts:
        o, l = ref.attention_with_lse(q, k[:, :, s0:s1], v[:, :, s0:s1], z[:, :, s0:s1])
        os_.append(o)
        ls_.append(l)
    om, lm = ref.merge_lse(os_[0], ls_[0], os_[1], ls_[1])
    om, lm = ref.merge_lse(om, lm, os_[2], ls_[2])
    np.testing.assert_allclose(np.asarray(om), np.asarray(of), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lm), np.asarray(lf), rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), split=st.integers(1, 39), scale=st.floats(0.1, 20.0))
def test_hypothesis_merge_union(seed, split, scale):
    (of, lf), (oa, la), (ob, lb) = _attn_parts(seed, split=split, scale=scale)
    om, lm = ref.merge_lse(oa, la, ob, lb)
    np.testing.assert_allclose(np.asarray(om), np.asarray(of), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lm), np.asarray(lf), rtol=2e-4, atol=2e-4)
