"""Trainer + weight export: loss decreases on the bundled corpus; the .hgw
round-trip is exact; corpus generation is deterministic."""

import numpy as np
import jax
import pytest

from compile import corpus, hgw, train
from compile.configs import TINY_SMALL
from compile.model import init_params


def test_corpus_deterministic():
    a = corpus.generate(n_bytes=4096)
    b = corpus.generate(n_bytes=4096)
    assert a == b
    assert len(a) == 4096
    assert all(ord(c) < 128 for c in a)  # pure ASCII → byte tokenizer covers it


def test_corpus_has_repeated_entities():
    text = corpus.generate(n_bytes=16384)
    # contextual locality requires long-range repetition
    hits = [text.count(e) for e in ["Arlington", "Galveston", "Austin"]]
    assert sum(1 for h in hits if h >= 2) >= 1


def test_hgw_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.normal(size=(3, 4)).astype(np.float32),
        "b.nested/name": rng.normal(size=(7,)).astype(np.float32),
        "scalar3d": rng.normal(size=(2, 2, 2)).astype(np.float32),
    }
    p = tmp_path / "t.hgw"
    hgw.save(str(p), tensors)
    out = hgw.load(str(p))
    assert set(out) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])


def test_params_to_tensors_covers_everything():
    params = init_params(TINY_SMALL, jax.random.PRNGKey(0))
    t = hgw.params_to_tensors(params)
    n = sum(int(np.prod(v.shape)) for v in t.values())
    assert n == TINY_SMALL.param_count()
    assert "layer0.wq" in t and "layer1.w2" in t and "tok_emb" in t


@pytest.mark.slow
def test_short_training_reduces_loss():
    data = np.frombuffer(corpus.generate(n_bytes=65536).encode(), dtype=np.uint8).astype(np.int32)
    _, losses = train.train_one(TINY_SMALL, data, steps=60, seed=0)
    first, last = losses[0][1], losses[-1][1]
    assert last < first * 0.8, f"loss did not drop: {first} -> {last}"
