"""Deterministic synthetic corpus generator (WikiText stand-in).

The container has no network access, so WikiText cannot be downloaded. This
module generates a fixed-seed, English-like corpus with the two statistical
properties the paper's analysis relies on:

* **repeated named entities** spread across long ranges -> contextual
  locality (paper Fig. 5: a few old KV entries stay influential), and
* **local syntactic structure** -> spatial locality / recency skew
  (Fig. 3/5) once a model is trained on it.

The generator is a template-grammar Markov-ish process; output is pure
ASCII so the byte-level tokenizer (vocab 256) covers it exactly. The same
text is produced on every run (fixed LCG seed), so artifacts are
reproducible bit-for-bit.
"""

import hashlib


class _Lcg:
    """Tiny deterministic PRNG (no numpy dependency for reproducibility)."""

    def __init__(self, seed: int):
        self.state = seed & 0xFFFFFFFFFFFFFFFF

    def next(self) -> int:
        self.state = (self.state * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        return self.state >> 33

    def choice(self, seq):
        return seq[self.next() % len(seq)]

    def randint(self, lo, hi):
        return lo + self.next() % (hi - lo + 1)


_ENTITIES = [
    "Arlington", "the Brazos River", "Fort Concho", "Palo Duro Canyon",
    "Governor Coke", "the Texas and Pacific Railway", "Colonel Mackenzie",
    "the Red River", "Judge Roy Bean", "the Chisholm Trail", "Galveston",
    "the Comanche nation", "Captain Goodnight", "the Llano Estacado",
    "the Rio Grande", "General Sheridan", "the Pecos valley", "Austin",
]

_SUBJECTS = [
    "The settlement", "The expedition", "The railway company", "The garrison",
    "A survey party", "The territorial legislature", "The cattle drive",
    "The river crossing", "The trading post", "The county court",
]

_VERBS = [
    "was established near", "expanded along", "negotiated with",
    "was abandoned after the flood at", "mapped the region around",
    "granted land adjacent to", "defended the route through",
    "recorded the first census of", "shipped grain from", "surveyed",
]

_CLAUSES = [
    "during the spring of that year", "despite repeated delays",
    "under the terms of the treaty", "before the winter storms arrived",
    "with support from the federal government", "after the drought ended",
    "at considerable expense", "according to contemporary accounts",
    "as noted in the annual report", "following the election",
]

_CONNECTORS = [
    "Meanwhile,", "In the following decade,", "By contrast,", "Soon after,",
    "Historical records show that", "According to later historians,",
    "In the same period,", "Two years later,",
]


def generate(n_bytes: int = 262144, seed: int = 0x48474341) -> str:  # "HGCA"
    rng = _Lcg(seed)
    out = []
    total = 0
    para_len = 0
    # each "document" gets a small set of focal entities, reused heavily ->
    # long-range repeated tokens (contextual locality).
    focal = [rng.choice(_ENTITIES) for _ in range(3)]
    while total < n_bytes:
        if para_len > rng.randint(400, 900):
            out.append("\n\n")
            total += 2
            para_len = 0
            if rng.randint(0, 3) == 0:  # new document, new focal entities
                focal = [rng.choice(_ENTITIES) for _ in range(3)]
                hdr = f"= {rng.choice(_ENTITIES).title()} =\n\n"
                out.append(hdr)
                total += len(hdr)
        ent = focal[rng.next() % 3] if rng.randint(0, 9) < 7 else rng.choice(_ENTITIES)
        parts = []
        if rng.randint(0, 2) == 0:
            parts.append(rng.choice(_CONNECTORS))
        parts.append(rng.choice(_SUBJECTS).lower() if parts else rng.choice(_SUBJECTS))
        parts.append(rng.choice(_VERBS))
        parts.append(ent)
        if rng.randint(0, 1) == 0:
            parts.append(rng.choice(_CLAUSES))
        if rng.randint(0, 4) == 0:
            parts.append(f"in 18{rng.randint(40, 99)}")
        sent = " ".join(parts) + ". "
        out.append(sent)
        total += len(sent)
        para_len += len(sent)
    text = "".join(out)[:n_bytes]
    return text


def corpus_sha(text: str) -> str:
    return hashlib.sha256(text.encode("ascii")).hexdigest()[:16]


def main() -> None:
    import sys

    out_path = sys.argv[1] if len(sys.argv) > 1 else "../data/corpus.txt"
    text = generate()
    with open(out_path, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} bytes, sha={corpus_sha(text)}")


if __name__ == "__main__":
    main()
