"""AOT lowering: JAX entry points → HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 crate links) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact takes model weights as runtime inputs, so a single compiled
executable serves all layers of a model. ``artifacts/manifest.json`` is the
contract with ``rust/src/runtime/artifacts.rs``: it records, per artifact,
the entry kind, static shapes (batch, window, chunk) and the exact input /
output order.

Usage: python -m compile.aot [--out ../artifacts] [--models tiny,...]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import DEFAULT_SHAPES, TRAINED_MODELS, ModelConfig
from . import model as M

F32 = jnp.float32
I32 = jnp.int32

# set by main() from --pallas; module-level so build_entries closures see it
USE_PALLAS = False


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io(names_shapes):
    return [{"name": n, "shape": list(s.shape), "dtype": str(s.dtype)} for n, s in names_shapes]


def build_entries(cfg: ModelConfig, B: int, W: int, C: int):
    """Yield (kind, name, fn, arg_specs, input_names, output_names) tuples."""
    D, H, dh, F, V = cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ffn, cfg.vocab

    for N, tag in ((1, "d"), (C, "p")):
        # ---- embed ----
        args = [
            ("tokens", _spec((B, N), I32)),
            ("positions", _spec((B, N), I32)),
            ("tok_emb", _spec((V, D))),
            ("pos_emb", _spec((cfg.max_pos, D))),
        ]
        yield ("embed", f"embed_{tag}_b{B}", M.embed, args,
               ["hidden"], [(B, N, D)])

        # ---- attn_step (GPU half of Algorithm 2) ----
        def attn_fn(hidden, ln1_g, ln1_b, wq, bq, wk, bk, wv, bv, k_win, v_win,
                    win_len, n_valid, _cfg=cfg):
            # use_pallas=False for CPU-PJRT serving artifacts (§Perf L2):
            # the interpret-mode pallas emulation is ~100x slower on the CPU
            # plugin; pass --pallas to embed the kernel (TPU-faithful path,
            # numerics identical — pytest pins kernel == ref oracle).
            return M.attn_step(_cfg, hidden, ln1_g, ln1_b, wq, bq, wk, bk, wv,
                               bv, k_win, v_win, win_len, n_valid,
                               use_pallas=USE_PALLAS)

        args = [
            ("hidden", _spec((B, N, D))),
            ("ln1_g", _spec((D,))), ("ln1_b", _spec((D,))),
            ("wq", _spec((D, D))), ("bq", _spec((D,))),
            ("wk", _spec((D, D))), ("bk", _spec((D,))),
            ("wv", _spec((D, D))), ("bv", _spec((D,))),
            ("k_win", _spec((B, H, W, dh))),
            ("v_win", _spec((B, H, W, dh))),
            ("win_len", _spec((B,), I32)),
            ("n_valid", _spec((B,), I32)),
        ]
        yield ("attn_step", f"attn_{tag}_b{B}_w{W}", attn_fn, args,
               ["q", "k_new", "v_new", "o_gpu", "lse", "a_sum"],
               [(B, H, N, dh)] * 4 + [(B, H, N), (B, H, W + N)])

        # ---- post_attn ----
        args = [
            ("hidden", _spec((B, N, D))),
            ("o_merged", _spec((B, N, D))),
            ("wo", _spec((D, D))), ("bo", _spec((D,))),
            ("ln2_g", _spec((D,))), ("ln2_b", _spec((D,))),
            ("w1", _spec((D, F))), ("b1", _spec((F,))),
            ("w2", _spec((F, D))), ("b2", _spec((D,))),
        ]
        yield ("post_attn", f"post_{tag}_b{B}", M.post_attn, args,
               ["hidden_out"], [(B, N, D)])

    # ---- lm_head (decode position only) ----
    args = [
        ("hidden", _spec((B, 1, D))),
        ("lnf_g", _spec((D,))), ("lnf_b", _spec((D,))),
        ("tok_emb", _spec((V, D))),
    ]
    yield ("lm_head", f"lm_head_b{B}", M.lm_head, args, ["logits"], [(B, 1, V)])


def lower_model(cfg: ModelConfig, shapes, out_dir: str, manifest: list, seen: set) -> None:
    for sh in shapes:
        for kind, name, fn, args, out_names, out_shapes in build_entries(
                cfg, sh.batch, sh.window, sh.chunk):
            full = f"{cfg.name}__{name}"
            if full in seen:
                continue
            seen.add(full)
            specs = [s for _, s in args]
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            fname = f"{full}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest.append({
                "model": cfg.name,
                "kind": kind,
                "name": full,
                "file": fname,
                "batch": sh.batch,
                "window": sh.window,
                "chunk": sh.chunk,
                "inputs": _io(args),
                "outputs": [{"name": n, "shape": list(s)} for n, s in zip(out_names, out_shapes)],
            })
            print(f"lowered {full} ({len(text)//1024} KiB)", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(c.name for c in TRAINED_MODELS))
    ap.add_argument("--fast", action="store_true",
                    help="only lower the (b=1,w=256) tiny variants (CI smoke)")
    ap.add_argument("--pallas", action="store_true",
                    help="embed the L1 pallas kernel in the attention "
                         "artifacts (TPU-faithful; slow under CPU interpret)")
    args = ap.parse_args()
    global USE_PALLAS
    USE_PALLAS = args.pallas
    os.makedirs(args.out, exist_ok=True)

    wanted = set(args.models.split(","))
    manifest = []
    seen = set()
    for cfg in TRAINED_MODELS:
        if cfg.name not in wanted:
            continue
        if args.fast or cfg.name != "tiny":
            shapes = [s for s in DEFAULT_SHAPES if s.batch == 1 and s.window == 256]
        else:
            shapes = DEFAULT_SHAPES
        lower_model(cfg, shapes, args.out, manifest, seen)

    models = {c.name: c.to_json_dict() for c in TRAINED_MODELS if c.name in wanted}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"version": 1, "models": models, "artifacts": manifest}, f, indent=1)
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
