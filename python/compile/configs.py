"""Shared model / artifact-shape configuration for the HGCA build path.

The same configs are mirrored on the rust side in ``rust/src/config/model.rs``
(presets ``tiny``, ``tiny-small``, ``tiny-large``). Any change here must be
reflected there; ``artifacts/manifest.json`` carries the authoritative shapes
so the rust runtime validates at load time.
"""

from dataclasses import dataclass, asdict, field
from typing import List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture of the (byte-level) decoder-only transformer."""

    name: str
    vocab: int = 256
    n_layers: int = 4
    d_model: int = 128
    n_heads: int = 4
    d_ffn: int = 512
    max_pos: int = 20480  # learned absolute positions (OPT-style)

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, l = self.d_model, self.d_ffn, self.n_layers
        per_layer = 4 * d * d + 4 * d + 2 * d * f + f + d + 4 * d  # qkvo + ffn + lns
        return self.vocab * d + self.max_pos * d + l * per_layer + 2 * d

    def to_json_dict(self) -> dict:
        dd = asdict(self)
        dd["d_head"] = self.d_head
        return dd


# Models actually trained + served end-to-end (real numerics).
TINY = ModelConfig(name="tiny", n_layers=4, d_model=128, n_heads=4, d_ffn=512)
TINY_SMALL = ModelConfig(name="tiny-small", n_layers=2, d_model=64, n_heads=2, d_ffn=256)
TINY_LARGE = ModelConfig(name="tiny-large", n_layers=6, d_model=192, n_heads=6, d_ffn=768)

TRAINED_MODELS = [TINY, TINY_SMALL, TINY_LARGE]


@dataclass(frozen=True)
class ArtifactShapes:
    """Static shapes compiled into the PJRT artifacts.

    batch: compiled batch size (engine pads with an active mask).
    window: GPU-resident KV window W (blk_num * blk_size on the rust side).
    chunk: prefill/append chunk length.
    """

    batch: int
    window: int
    chunk: int


# Compiled variants. The engine selects the smallest fitting (batch, window).
DEFAULT_SHAPES: List[ArtifactShapes] = [
    ArtifactShapes(batch=1, window=256, chunk=64),
    ArtifactShapes(batch=4, window=256, chunk=64),
    ArtifactShapes(batch=1, window=1024, chunk=64),
    ArtifactShapes(batch=4, window=1024, chunk=64),
]

# Pallas kernel tiling (see DESIGN.md §6). block_k must divide padded S.
BLOCK_Q = 64
BLOCK_K = 128
