"""Build-time trainer for the demo models (real numerics for the accuracy
experiments, Table 1 / Figs. 3–5, 15).

Trains byte-level decoder-only transformers (configs.TRAINED_MODELS) on the
bundled deterministic corpus with Adam, then exports weights as
``artifacts/<name>.hgw`` + ``artifacts/<name>_config.json``. Runs once under
``make artifacts``; never on the serving path.

Usage: python -m compile.train [--steps N] [--out DIR] [--models tiny,...]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus as corpus_mod
from . import hgw
from .configs import TRAINED_MODELS, ModelConfig
from .model import Params, full_forward, init_params

SEQ_LEN = 256
BATCH = 8


def load_corpus(repo_root: str) -> np.ndarray:
    path = os.path.join(repo_root, "data", "corpus.txt")
    if not os.path.exists(path):
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(corpus_mod.generate())
    with open(path, "rb") as f:
        data = np.frombuffer(f.read(), dtype=np.uint8)
    return data.astype(np.int32)


def batches(data: np.ndarray, rng: np.random.Generator, batch: int, seq: int):
    while True:
        idx = rng.integers(0, len(data) - seq - 1, size=batch)
        x = np.stack([data[i:i + seq] for i in idx])
        y = np.stack([data[i + 1:i + seq + 1] for i in idx])
        yield jnp.asarray(x), jnp.asarray(y)


def loss_fn(cfg: ModelConfig, params: Params, x, y):
    logits = full_forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def adam_update(grads, params_flat, m, v, step, lr=3e-4, b1=0.9, b2=0.999, eps=1e-8):
    new_p, new_m, new_v = [], [], []
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    for g, p, mi, vi in zip(grads, params_flat, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        p = p - lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
        new_p.append(p)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def train_one(cfg: ModelConfig, data: np.ndarray, steps: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    flat, treedef = jax.tree.flatten(params)
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]

    @jax.jit
    def step_fn(flat, m, v, step, x, y):
        params = jax.tree.unflatten(treedef, flat)
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, x, y))(params)
        gflat = jax.tree.flatten(grads)[0]
        flat, m, v = adam_update(gflat, flat, m, v, step)
        return flat, m, v, loss

    rng = np.random.default_rng(seed + 1)
    gen = batches(data, rng, BATCH, SEQ_LEN)
    losses = []
    t0 = time.time()
    for i in range(1, steps + 1):
        x, y = next(gen)
        flat, m, v, loss = step_fn(flat, m, v, jnp.float32(i), x, y)
        if i == 1 or i % 50 == 0 or i == steps:
            lv = float(loss)
            losses.append((i, lv))
            print(f"[{cfg.name}] step {i:4d} loss {lv:.4f} ppl {np.exp(lv):8.2f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return jax.tree.unflatten(treedef, flat), losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(c.name for c in TRAINED_MODELS))
    args = ap.parse_args()

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(os.path.dirname(__file__))))
    os.makedirs(args.out, exist_ok=True)
    data = load_corpus(repo_root)
    print(f"corpus: {len(data)} bytes, sha={corpus_mod.corpus_sha(bytes(data.astype(np.uint8)).decode('ascii'))}")

    wanted = set(args.models.split(","))
    log = {}
    for cfg in TRAINED_MODELS:
        if cfg.name not in wanted:
            continue
        params, losses = train_one(cfg, data, args.steps)
        hgw.save(os.path.join(args.out, f"{cfg.name}.hgw"), hgw.params_to_tensors(params))
        with open(os.path.join(args.out, f"{cfg.name}_config.json"), "w") as f:
            json.dump(cfg.to_json_dict(), f, indent=1)
        log[cfg.name] = {"params": cfg.param_count(), "loss_curve": losses}
        print(f"[{cfg.name}] exported {cfg.param_count()} params")
    with open(os.path.join(args.out, "train_log.json"), "w") as f:
        json.dump(log, f, indent=1)


if __name__ == "__main__":
    main()
