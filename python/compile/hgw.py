"""`.hgw` — the tiny binary weight format shared with the rust loader.

Layout (little-endian):
  magic  b"HGW1"
  u32    n_tensors
  per tensor:
    u16    name_len, name (utf-8)
    u8     ndim
    u32*   dims
    f32*   row-major data

The rust loader lives in ``rust/src/tensor/weights.rs``; keep the two in
lockstep.
"""

import struct
from typing import Dict

import numpy as np

MAGIC = b"HGW1"


def save(path: str, tensors: Dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def load(path: str) -> Dict[str, np.ndarray]:
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<H", f.read(2))
            name = f.read(nl).decode("utf-8")
            (nd,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd))
            cnt = int(np.prod(dims)) if nd else 1
            data = np.frombuffer(f.read(4 * cnt), dtype="<f4").reshape(dims)
            out[name] = data
    return out


def params_to_tensors(params) -> Dict[str, np.ndarray]:
    """Flatten a model.Params into the .hgw name space."""
    t = {
        "tok_emb": np.asarray(params.tok_emb),
        "pos_emb": np.asarray(params.pos_emb),
        "lnf_g": np.asarray(params.lnf_g),
        "lnf_b": np.asarray(params.lnf_b),
    }
    for i, lp in enumerate(params.layers):
        for fname in lp._fields:
            t[f"layer{i}.{fname}"] = np.asarray(getattr(lp, fname))
    return t
