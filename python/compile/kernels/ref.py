"""Pure-jnp oracles for the L1 kernels.

These are the ground truth the pallas kernels are verified against (pytest +
hypothesis) and also the semantics the rust-side CPU attention and LSE merge
replicate (rust/src/attention/). Keep them boring and obviously correct.
"""

import jax.numpy as jnp


def attention_with_lse(q, k, v, bias):
    """Dense attention with log-sum-exp statistics.

    q:    [B, H, N, dh]   (already scaled by 1/sqrt(dh))
    k, v: [B, H, S, dh]
    bias: [B, N, S]       additive mask, 0 for valid, -inf (large neg) invalid
    returns (o [B,H,N,dh], lse [B,H,N])

    lse is the *raw* log-sum-exp of the masked scores, the quantity used by
    the FlashAttention-style merge: softmax_i = exp(s_i - lse).
    """
    s = jnp.einsum("bhnd,bhsd->bhns", q, k) + bias[:, None, :, :]
    m = jnp.max(s, axis=-1, keepdims=True)
    # guard fully-masked rows: keep m finite so exp() stays well-defined
    m = jnp.maximum(m, -1e30)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhns,bhsd->bhnd", p, v) / jnp.maximum(l, 1e-30)
    lse = (m + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]
    return o, lse


def attention_probs(q, k, bias, lse):
    """Recover per-slot softmax probabilities from scores + lse.

    returns probs [B, H, N, S]; rows whose slots are masked get ~0.
    """
    s = jnp.einsum("bhnd,bhsd->bhns", q, k) + bias[:, None, :, :]
    return jnp.exp(s - lse[..., None])


def merge_lse(o_a, lse_a, o_b, lse_b):
    """FlashAttention/FlashInfer-style merge of two partial attentions.

    Each (o, lse) pair is a locally-normalized attention over a disjoint set
    of KV entries. Returns the (o, lse) of attention over the union — the
    paper's "merging states" (§3.3), numerically stabilized.

    o_*:   [..., dh], lse_*: [...]
    """
    m = jnp.maximum(lse_a, lse_b)
    wa = jnp.exp(lse_a - m)
    wb = jnp.exp(lse_b - m)
    z = wa + wb
    o = (wa[..., None] * o_a + wb[..., None] * o_b) / z[..., None]
    lse = m + jnp.log(z)
    return o, lse
