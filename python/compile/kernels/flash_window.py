"""L1 Pallas kernel: dense window attention with LSE output.

This is the paper's GPU-side hot spot (the "GPU-local dense attention" of
HGCA §3.3) re-thought for TPU per DESIGN.md §6:

* grid = (B*H, ceil(N / BLOCK_Q)) — one program per (head, q-tile);
* the KV window is streamed tile-by-tile (BLOCK_K) through an online-softmax
  loop (`lax.fori_loop`), the FlashAttention schedule. On TPU each tile is an
  HBM→VMEM copy feeding the MXU; `interpret=True` (mandatory on the CPU PJRT
  plugin — Mosaic custom-calls cannot run there) executes the same schedule
  with numpy semantics, so numerics and loop structure are what we validate.
* outputs are the partial attention O *and* the raw log-sum-exp, which the
  rust coordinator merges with the CPU-side sparse attention
  (Algorithm 2, line 13).

Shapes: q [B,H,N,dh] (pre-scaled), k/v [B,H,S,dh], bias [B,N,S] additive
mask. S must be a multiple of BLOCK_K (the L2 wrapper pads and masks).
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..configs import BLOCK_Q, BLOCK_K

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, lse_ref, *, block_k: int):
    """One (head, q-tile) program: online softmax over KV tiles."""
    q = q_ref[0, 0]  # [bq, dh]
    bq, dh = q.shape
    s_total = k_ref.shape[2]
    n_kv = s_total // block_k

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (0, 0, pl.dslice(j * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, 0, pl.dslice(j * block_k, block_k), slice(None)))
        b = pl.load(bias_ref, (0, slice(None), pl.dslice(j * block_k, block_k)))
        s = jnp.dot(q, k.T) + b  # [bq, bk] — MXU matmul on real TPU
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        # rescale previous accumulator to the new running max
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, dh), dtype=jnp.float32)
    m, l, acc = lax.fori_loop(0, n_kv, body, (m0, l0, acc0))

    l_safe = jnp.maximum(l, 1e-30)
    o_ref[0, 0] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[0, 0] = (m + jnp.log(l_safe)).astype(lse_ref.dtype)


def flash_window_attention(q, k, v, bias, *, block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                           interpret: bool = True):
    """Tiled dense attention with LSE. See module docstring for shapes.

    Pads N up to a multiple of block_q and S up to a multiple of block_k
    internally; padded KV slots are masked via `bias` padding with NEG_INF,
    padded query rows are dropped from the output.
    """
    B, H, N, dh = q.shape
    S = k.shape[2]
    bq = min(block_q, _ceil_to(N, 8))
    bk = min(block_k, _ceil_to(S, 8))

    n_pad = _ceil_to(N, bq) - N
    s_pad = _ceil_to(S, bk) - S
    if n_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, n_pad), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, n_pad), (0, 0)))
    if s_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, 0), (0, s_pad)), constant_values=NEG_INF)

    Np, Sp = N + n_pad, S + s_pad
    grid = (B * H, Np // bq)

    out_shapes = (
        jax.ShapeDtypeStruct((B, H, Np, dh), jnp.float32),
        jax.ShapeDtypeStruct((B, H, Np), jnp.float32),
    )
    kernel = functools.partial(_flash_kernel, block_k=bk)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda bh, qb: (bh // H, bh % H, qb, 0)),
            pl.BlockSpec((1, 1, Sp, dh), lambda bh, qb: (bh // H, bh % H, 0, 0)),
            pl.BlockSpec((1, 1, Sp, dh), lambda bh, qb: (bh // H, bh % H, 0, 0)),
            pl.BlockSpec((1, bq, Sp), lambda bh, qb: (bh // H, qb, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, bq, dh), lambda bh, qb: (bh // H, bh % H, qb, 0)),
            pl.BlockSpec((1, 1, bq), lambda bh, qb: (bh // H, bh % H, qb)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(q, k, v, bias)

    if n_pad:
        o = o[:, :, :N]
        lse = lse[:, :, :N]
    return o, lse


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def vmem_footprint_bytes(block_q: int = BLOCK_Q, block_k: int = BLOCK_K, dh: int = 32) -> int:
    """Estimated VMEM bytes per grid step (DESIGN.md §6): q-tile + k/v tile +
    score tile + accumulator, fp32."""
    return 4 * (block_q * dh + 2 * block_k * dh + block_q * block_k + block_q * dh)
