"""L2: the transformer compute graph (JAX, build-time only).

Decoder-only, OPT-style: byte-level vocab, learned absolute positional
embeddings, pre-LayerNorm blocks, GELU FFN, tied LM head. The per-layer
attention step is split exactly along the paper's hybrid boundary:

  * ``attn_step``  — everything the "GPU" does for one layer (Algorithm 2,
    line 10): LN → QKV projection → dense windowed attention over the
    GPU-resident KV window (the L1 pallas kernel) → (O_gpu, LSE_gpu) plus the
    per-slot attention mass A_gpu used for MAW tracking (Algorithm 1, line 8).
  * the CPU sparse attention runs in rust between the two artifacts;
  * ``post_attn`` — output projection + residual + FFN, consuming the merged
    attention output.

All entry points take weights as *inputs* so one compiled artifact serves
every layer. ``full_forward`` is the monolithic causal forward used for
training and as the python-side oracle.
"""

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.flash_window import flash_window_attention, NEG_INF
from .kernels import ref


class LayerParams(NamedTuple):
    ln1_g: jax.Array
    ln1_b: jax.Array
    wq: jax.Array
    bq: jax.Array
    wk: jax.Array
    bk: jax.Array
    wv: jax.Array
    bv: jax.Array
    wo: jax.Array
    bo: jax.Array
    ln2_g: jax.Array
    ln2_b: jax.Array
    w1: jax.Array
    b1: jax.Array
    w2: jax.Array
    b2: jax.Array


class Params(NamedTuple):
    tok_emb: jax.Array  # [vocab, d]
    pos_emb: jax.Array  # [max_pos, d]
    layers: list        # list[LayerParams]
    lnf_g: jax.Array
    lnf_b: jax.Array


def init_params(cfg: ModelConfig, key) -> Params:
    d, f = cfg.d_model, cfg.d_ffn
    keys = jax.random.split(key, 2 + cfg.n_layers)
    std = 0.02

    def dense(k, i, o):
        return jax.random.normal(k, (i, o), jnp.float32) * std

    layers = []
    for li in range(cfg.n_layers):
        ks = jax.random.split(keys[2 + li], 6)
        layers.append(LayerParams(
            ln1_g=jnp.ones((d,)), ln1_b=jnp.zeros((d,)),
            wq=dense(ks[0], d, d), bq=jnp.zeros((d,)),
            wk=dense(ks[1], d, d), bk=jnp.zeros((d,)),
            wv=dense(ks[2], d, d), bv=jnp.zeros((d,)),
            wo=dense(ks[3], d, d), bo=jnp.zeros((d,)),
            ln2_g=jnp.ones((d,)), ln2_b=jnp.zeros((d,)),
            w1=dense(ks[4], d, f), b1=jnp.zeros((f,)),
            w2=dense(ks[5], f, d), b2=jnp.zeros((d,)),
        ))
    return Params(
        tok_emb=jax.random.normal(keys[0], (cfg.vocab, d), jnp.float32) * std,
        pos_emb=jax.random.normal(keys[1], (cfg.max_pos, d), jnp.float32) * std,
        layers=layers,
        lnf_g=jnp.ones((d,)), lnf_b=jnp.zeros((d,)),
    )


def layernorm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def gelu(x):
    # tanh approximation — mirrored exactly in rust/src/tensor/ops.rs
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


# ---------------------------------------------------------------------------
# AOT entry points (weights passed as inputs; shapes static per artifact)
# ---------------------------------------------------------------------------

def embed(tokens, positions, tok_emb, pos_emb):
    """tokens/positions i32[B,N] → hidden f32[B,N,D]."""
    return tok_emb[tokens] + pos_emb[positions]


def _split_heads(x, n_heads):
    B, N, D = x.shape
    dh = D // n_heads
    return x.reshape(B, N, n_heads, dh).transpose(0, 2, 1, 3)


def attn_step(cfg: ModelConfig, hidden, ln1_g, ln1_b, wq, bq, wk, bk, wv, bv,
              k_win, v_win, win_len, n_valid, use_pallas: bool = True):
    """GPU-side half of one hybrid attention layer (Algorithm 2, line 10).

    hidden:       f32[B, N, D]    (N=1 decode, N=chunk append/prefill)
    k_win, v_win: f32[B, H, W, dh] GPU-resident window, chronological order,
                  only the first win_len[b] slots valid.
    win_len:      i32[B]
    n_valid:      i32[B]  valid query rows per sequence (chunk padding: the
                  tail rows beyond n_valid are inert — masked out of a_sum
                  and never appended by the coordinator)

    Returns:
      q      f32[B,H,N,dh]  scaled queries (consumed by rust CPU attention)
      k_new  f32[B,H,N,dh]  new KV entries (rust appends them to the window)
      v_new  f32[B,H,N,dh]
      o_gpu  f32[B,H,N,dh]  partial attention over [window ; new tokens]
      lse    f32[B,H,N]
      a_sum  f32[B,H,W+N]   per-slot attention mass summed over the valid
                            queries (MAW update, Algorithm 1 line 8)
    """
    B, N, D = hidden.shape
    H, dh = cfg.n_heads, cfg.d_head
    W = k_win.shape[2]
    x = layernorm(hidden, ln1_g, ln1_b)
    q = _split_heads(x @ wq + bq, H) * (1.0 / math.sqrt(dh))
    k_new = _split_heads(x @ wk + bk, H)
    v_new = _split_heads(x @ wv + bv, H)

    k_all = jnp.concatenate([k_win, k_new], axis=2)  # [B,H,W+N,dh]
    v_all = jnp.concatenate([v_win, v_new], axis=2)

    # slot validity: window slot j valid iff j < win_len[b];
    # new slot W+i visible to query n iff i <= n (causal within the chunk)
    # and i < n_valid[b] (padded KV slots are never attended).
    slot = jnp.arange(W + N)[None, None, :]                      # [1,1,S]
    qpos = jnp.arange(N)[None, :, None]                          # [1,N,1]
    valid_win = slot < win_len[:, None, None]                    # [B,1,S]
    valid_new = (slot >= W) & ((slot - W) <= qpos) \
        & ((slot - W) < n_valid[:, None, None])                  # [B,N,S]
    bias = jnp.where(valid_win | valid_new, 0.0, NEG_INF).astype(jnp.float32)
    bias = jnp.broadcast_to(bias, (B, N, W + N))

    if use_pallas:
        # L1 pallas flash kernel — the TPU-targeted path (Mosaic on real
        # hardware; interpret=True emulation on the CPU PJRT plugin).
        o_gpu, lse = flash_window_attention(q, k_all, v_all, bias)
    else:
        # XLA-fused equivalent for CPU-serving artifacts (§Perf L2): the
        # interpret-mode grid emulation costs ~100x on the CPU plugin;
        # numerics are identical (pytest pins kernel == ref).
        o_gpu, lse = ref.attention_with_lse(q, k_all, v_all, bias)
    probs = ref.attention_probs(q, k_all, bias, lse)             # [B,H,N,S]
    # zero out padded query rows so their mass never reaches the MAW
    q_mask = (jnp.arange(N)[None, :] < n_valid[:, None]).astype(jnp.float32)
    a_sum = jnp.einsum("bhns,bn->bhs", probs, q_mask)            # [B,H,S]
    return q, k_new, v_new, o_gpu, lse, a_sum


def post_attn(hidden, o_merged, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2):
    """Output projection + residual + FFN, after the rust-side LSE merge.

    hidden:   f32[B,N,D] residual input (same tensor attn_step consumed)
    o_merged: f32[B,N,D] merged attention output, heads already flattened
    """
    h = hidden + (o_merged @ wo + bo)
    x = layernorm(h, ln2_g, ln2_b)
    return h + (gelu(x @ w1 + b1) @ w2 + b2)


def lm_head(hidden, lnf_g, lnf_b, tok_emb):
    """hidden f32[B,N,D] → logits f32[B,N,vocab] (tied embedding)."""
    return layernorm(hidden, lnf_g, lnf_b) @ tok_emb.T


# ---------------------------------------------------------------------------
# Monolithic forward (training + oracle)
# ---------------------------------------------------------------------------

def full_forward(cfg: ModelConfig, params: Params, tokens):
    """Standard full causal attention over tokens i32[B,T] → logits [B,T,V]."""
    B, T = tokens.shape
    H, dh = cfg.n_heads, cfg.d_head
    h = embed(tokens, jnp.broadcast_to(jnp.arange(T)[None, :], (B, T)),
              params.tok_emb, params.pos_emb)
    causal = jnp.where(jnp.arange(T)[:, None] >= jnp.arange(T)[None, :], 0.0, NEG_INF)
    bias = jnp.broadcast_to(causal[None], (B, T, T)).astype(jnp.float32)
    for lp in params.layers:
        x = layernorm(h, lp.ln1_g, lp.ln1_b)
        q = _split_heads(x @ lp.wq + lp.bq, H) * (1.0 / math.sqrt(dh))
        k = _split_heads(x @ lp.wk + lp.bk, H)
        v = _split_heads(x @ lp.wv + lp.bv, H)
        o, _ = ref.attention_with_lse(q, k, v, bias)
        o = o.transpose(0, 2, 1, 3).reshape(B, T, cfg.d_model)
        h = post_attn(h, o, lp.wo, lp.bo, lp.ln2_g, lp.ln2_b, lp.w1, lp.b1, lp.w2, lp.b2)
    return lm_head(h, params.lnf_g, params.lnf_b, params.tok_emb)


def full_forward_attn_probs(cfg: ModelConfig, params: Params, tokens):
    """Forward that also returns per-layer attention probabilities
    [L][B,H,T,T] — used by the analysis benches (paper Figs. 3–5)."""
    B, T = tokens.shape
    H, dh = cfg.n_heads, cfg.d_head
    h = embed(tokens, jnp.broadcast_to(jnp.arange(T)[None, :], (B, T)),
              params.tok_emb, params.pos_emb)
    causal = jnp.where(jnp.arange(T)[:, None] >= jnp.arange(T)[None, :], 0.0, NEG_INF)
    bias = jnp.broadcast_to(causal[None], (B, T, T)).astype(jnp.float32)
    all_probs = []
    for lp in params.layers:
        x = layernorm(h, lp.ln1_g, lp.ln1_b)
        q = _split_heads(x @ lp.wq + lp.bq, H) * (1.0 / math.sqrt(dh))
        k = _split_heads(x @ lp.wk + lp.bk, H)
        v = _split_heads(x @ lp.wv + lp.bv, H)
        o, lse = ref.attention_with_lse(q, k, v, bias)
        all_probs.append(ref.attention_probs(q, k, bias, lse))
        o = o.transpose(0, 2, 1, 3).reshape(B, T, cfg.d_model)
        h = post_attn(h, o, lp.wo, lp.bo, lp.ln2_g, lp.ln2_b, lp.w1, lp.b1, lp.w2, lp.b2)
    return lm_head(h, params.lnf_g, params.lnf_b, params.tok_emb), all_probs
